"""Figure 3 — top-k expert-selection overlap |E_i ∩ E_j| for
(1) consecutive tokens of the same request (the speculative-token proxy),
(2) two tokens from the same dataset, (3) two tokens from different
datasets — on a trained router over heterogeneous synthetic datasets."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import DATASETS, trained_model
from repro.models import forward

KS = (5, 10, 15, 30)


def _router_gates(cfg, params, tokens):
    """Per-token full router probabilities at layer 0."""
    import repro.models.attention as A
    from repro.models.layers import rms_norm
    from repro.models.model import embed_tokens
    x = embed_tokens(cfg, params, jnp.asarray(tokens))
    lp = jax.tree_util.tree_map(lambda a: a[0], params["layers"])
    B, S = x.shape[:2]
    positions = jnp.arange(S)[None, :].repeat(B, axis=0)
    h = rms_norm(x, lp["attn_norm"], cfg.norm_eps)
    q, k, v = A.qkv_project(lp["attn"], h, positions, cfg.attn)
    a = A.flash_attention(q, k, v)
    x = x + a.reshape(B, S, -1) @ lp["attn"]["wo"]
    h = rms_norm(x, lp["moe_norm"], cfg.norm_eps)
    logits = jnp.asarray(h, jnp.float32) @ lp["moe"]["wg"]
    return np.asarray(jax.nn.softmax(logits, -1))   # (B,S,E)


def run() -> dict:
    cfg, params, fam, _ = trained_model(32, 4)
    rng = np.random.default_rng(0)
    seqs = {n: fam[n].sample(rng, 8, 32) for n in DATASETS}
    gates = {n: _router_gates(cfg, params, s) for n, s in seqs.items()}

    def topk_sets(g, k):
        return np.argsort(-g, axis=-1)[..., :k]

    rows = []
    for k in KS:
        k_eff = min(k, cfg.moe.num_experts)
        spec, same, cross = [], [], []
        for n in DATASETS:
            t = topk_sets(gates[n], k_eff)          # (B,S,k)
            B, S = t.shape[:2]
            for b in range(B):
                for s in range(S - 1):               # consecutive tokens
                    spec.append(len(np.intersect1d(t[b, s], t[b, s + 1])))
            for _ in range(64):                      # same dataset pairs
                b1, b2 = rng.integers(B, size=2)
                s1, s2 = rng.integers(S, size=2)
                same.append(len(np.intersect1d(t[b1, s1], t[b2, s2])))
        names = list(DATASETS)
        for _ in range(128):                         # cross dataset pairs
            n1, n2 = rng.choice(len(names), 2, replace=False)
            t1 = topk_sets(gates[names[n1]], k_eff)
            t2 = topk_sets(gates[names[n2]], k_eff)
            b1, s1 = rng.integers(8), rng.integers(32)
            b2, s2 = rng.integers(8), rng.integers(32)
            cross.append(len(np.intersect1d(t1[b1, s1], t2[b2, s2])))
        rows.append({"k": k_eff, "consecutive": float(np.mean(spec)),
                     "same_dataset": float(np.mean(same)),
                     "cross_dataset": float(np.mean(cross))})
    # paper claim: consecutive-token overlap ~2-3x cross-dataset overlap
    r = rows[0]
    ratio = r["consecutive"] / max(r["cross_dataset"], 1e-9)
    return {"rows": rows, "k5_ratio_spec_vs_cross": ratio}
