"""Kernel-level benchmark: the XShare masked MoE FFN's byte-traffic
model vs activation count (the mechanism behind every OTPS number), plus
oracle-path wall times on CPU for scale reference. The Pallas kernel
itself runs in interpret mode here (Python), so its wall time is not
meaningful; the HBM-byte model is what transfers to TPU."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.ops import moe_step_bytes, xshare_moe_ffn
from repro.kernels.ref import moe_ffn_ref


def run() -> dict:
    T, d, E, f = 32, 256, 32, 512
    ks = jax.random.split(jax.random.PRNGKey(0), 6)
    x = jax.random.normal(ks[0], (T, d), jnp.float32)
    w1 = jax.random.normal(ks[1], (E, d, f)) * 0.05
    w3 = jax.random.normal(ks[2], (E, d, f)) * 0.05
    w2 = jax.random.normal(ks[3], (E, f, d)) * 0.05
    logits = jax.random.normal(ks[4], (T, E))
    top, idx = jax.lax.top_k(logits, 4)
    w = jax.nn.softmax(top, -1)
    combine_full = (jax.nn.one_hot(idx, E) * w[..., None]).sum(-2)

    ref_jit = jax.jit(moe_ffn_ref)
    rows = []
    for n_act in (32, 24, 16, 8, 4):
        active = jnp.arange(E) < n_act
        combine = jnp.where(active[None], combine_full, 0.0)
        # correctness cross-check on this activation pattern
        out_k = xshare_moe_ffn(x, w1, w3, w2, combine, active,
                               max_active=n_act, block_f=128)
        out_r = ref_jit(x, w1, w3, w2, combine, active)
        err = float(jnp.abs(out_k - out_r).max())
        # oracle wall time (dense path: no savings — the contrast point)
        ref_jit(x, w1, w3, w2, combine, active).block_until_ready()
        t0 = time.perf_counter()
        for _ in range(20):
            ref_jit(x, w1, w3, w2, combine, active).block_until_ready()
        wall_us = (time.perf_counter() - t0) / 20 * 1e6
        bytes_model = moe_step_bytes(n_act, d, f, tokens=T, top_k=4)
        rows.append({"active": n_act, "kernel_vs_ref_err": err,
                     "dense_ref_us": wall_us,
                     "hbm_bytes_model": bytes_model,
                     "bytes_rel": bytes_model
                     / moe_step_bytes(E, d, f, tokens=T, top_k=4)})
    return {"rows": rows,
            "bytes_at_quarter_activation": rows[-2]["bytes_rel"]}
