"""Kernel-level benchmark, two parts.

1. The XShare masked MoE FFN's byte-traffic model vs activation count
   (the mechanism behind every OTPS number), plus oracle-path wall
   times on CPU for scale reference. The Pallas kernel itself runs in
   interpret mode here (Python), so its wall time is not meaningful;
   the HBM-byte model is what transfers to TPU.

2. Dispatch-path shootout at prefill scale (T >= 2048, E >= 32):
   sort-based grouped-GEMM dispatch vs the GShard one-hot einsum
   reference, wall time (tokens/s) and peak dispatch-intermediate
   bytes. Both paths are real XLA-compiled model code
   (models/moe.expert_ffn dispatch switch), so the CPU wall-time ratio
   reflects the structural work each path does — the (G,t,E,C) one-hot
   build + dispatch/combine einsums vs sort + gather + tile GEMM +
   scatter. Results persist to BENCH_dispatch.json at the repo root so
   the perf trajectory is tracked PR over PR.
"""
from __future__ import annotations

import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import MoEConfig
from repro.kernels.ops import (dispatch_einsum_bytes, dispatch_sorted_bytes,
                               moe_step_bytes, xshare_moe_ffn)
from repro.kernels.ref import moe_ffn_ref
from repro.models.dispatch import default_block_t
from repro.models.moe import (OFF, einsum_capacity, expert_ffn, init_moe,
                              route)

BENCH_PATH = os.path.join(os.path.dirname(__file__), os.pardir,
                          "BENCH_dispatch.json")


def _time(fn, *args, iters=3):
    fn(*args).block_until_ready()          # compile + warm
    t0 = time.perf_counter()
    for _ in range(iters):
        fn(*args).block_until_ready()
    return (time.perf_counter() - t0) / iters


def dispatch_shootout(T: int = 2048, E: int = 32, k: int = 4,
                      d: int = 256, f: int = 512,
                      capacity_factor: float = 1.25) -> dict:
    moe = MoEConfig(num_experts=E, top_k=k, d_ff_expert=f)
    p = init_moe(jax.random.PRNGKey(0), moe, d, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (T, d))
    idx, w, combine, _ = route(p, x, moe, OFF)

    sorted_fn = jax.jit(lambda x, idx, w: expert_ffn(
        p, x, idx, w, moe, dispatch="sorted"))
    einsum_fn = jax.jit(lambda x, idx, w: expert_ffn(
        p, x, idx, w, moe, dispatch="einsum",
        capacity_factor=capacity_factor, group_size=T))

    err = float(jnp.abs(
        sorted_fn(x, idx, w)
        - expert_ffn(p, x, idx, w, moe, dispatch="einsum", capacity=T,
                     group_size=10**9)).max())

    t_sorted = _time(sorted_fn, x, idx, w)
    t_einsum = _time(einsum_fn, x, idx, w)

    C = einsum_capacity(T, k, E, capacity_factor)  # group_size=T => G=1
    bt = default_block_t(T * k, E)
    b_einsum = dispatch_einsum_bytes(T, E, C, d)
    b_sorted = dispatch_sorted_bytes(T, k, E, d, block_t=bt)
    # the CPU fallback (tile-gather einsum) additionally materializes
    # per-tile weight copies the TPU kernel streams instead — reported
    # separately so the dispatch-intermediate trend stays honest about
    # what this box actually allocates
    nt = (T * k + min(E, T * k) * (bt - 1) + bt - 1) // bt
    b_weight_gather = nt * 3 * d * f * 4
    return {
        "shape": {"T": T, "E": E, "top_k": k, "d_model": d, "d_ff": f,
                  "einsum_capacity": C},
        "sorted_wall_ms": t_sorted * 1e3,
        "einsum_wall_ms": t_einsum * 1e3,
        "sorted_tokens_per_s": T / t_sorted,
        "einsum_tokens_per_s": T / t_einsum,
        "speedup": t_einsum / t_sorted,
        "sorted_dispatch_bytes": b_sorted,
        "einsum_dispatch_bytes": b_einsum,
        "bytes_ratio": b_einsum / b_sorted,
        "sorted_jnp_weight_gather_bytes": b_weight_gather,
        "sorted_vs_einsum_err": err,
    }


def run(quick: bool = False) -> dict:
    T, d, E, f = 32, 256, 32, 512
    ks = jax.random.split(jax.random.PRNGKey(0), 6)
    x = jax.random.normal(ks[0], (T, d), jnp.float32)
    w1 = jax.random.normal(ks[1], (E, d, f)) * 0.05
    w3 = jax.random.normal(ks[2], (E, d, f)) * 0.05
    w2 = jax.random.normal(ks[3], (E, f, d)) * 0.05
    logits = jax.random.normal(ks[4], (T, E))
    top, idx = jax.lax.top_k(logits, 4)
    w = jax.nn.softmax(top, -1)
    combine_full = (jax.nn.one_hot(idx, E) * w[..., None]).sum(-2)

    ref_jit = jax.jit(moe_ffn_ref)
    rows = []
    for n_act in (32, 8, 4) if quick else (32, 24, 16, 8, 4):
        active = jnp.arange(E) < n_act
        combine = jnp.where(active[None], combine_full, 0.0)
        # correctness cross-check on this activation pattern
        out_k = xshare_moe_ffn(x, w1, w3, w2, combine, active,
                               max_active=n_act, block_f=128)
        out_r = ref_jit(x, w1, w3, w2, combine, active)
        err = float(jnp.abs(out_k - out_r).max())
        # oracle wall time (dense path: no savings — the contrast point)
        ref_jit(x, w1, w3, w2, combine, active).block_until_ready()
        t0 = time.perf_counter()
        for _ in range(20):
            ref_jit(x, w1, w3, w2, combine, active).block_until_ready()
        wall_us = (time.perf_counter() - t0) / 20 * 1e6
        bytes_model = moe_step_bytes(n_act, d, f, tokens=T, top_k=4)
        rows.append({"active": n_act, "kernel_vs_ref_err": err,
                     "dense_ref_us": wall_us,
                     "hbm_bytes_model": bytes_model,
                     "bytes_rel": bytes_model
                     / moe_step_bytes(E, d, f, tokens=T, top_k=4)})

    shoot = dispatch_shootout(T=1024 if quick else 2048, E=32, k=4,
                              d=128 if quick else 256,
                              f=256 if quick else 512)
    with open(BENCH_PATH, "w") as fh:
        json.dump({"dispatch": shoot}, fh, indent=1, default=float)

    quarter = next((r for r in rows if r["active"] == E // 4), rows[-1])
    return {"rows": rows,
            "bytes_at_quarter_activation": quarter["bytes_rel"],
            "dispatch": shoot,
            "dispatch_speedup": shoot["speedup"],
            "dispatch_bytes_ratio": shoot["bytes_ratio"]}
