"""Table 1 / Figure 6 — heterogeneous mixed-request batches: one request
from each of four distinct datasets, speculation length 3. Verifies the
hierarchical selection stays robust when requests are domain-diverse
(per-request budgets isolate each domain's experts)."""
from __future__ import annotations

import numpy as np

from benchmarks.common import (DATASETS, otps_model,
                               teacher_forced_decode_ce, trained_model)
from repro.configs.base import XSharePolicy
from repro.data import mixed_request_batch

CONFIGS = [(0, 1, 4), (1, 0, 1), (1, 0, 2), (2, 0, 1), (1, 6, 0),
           (0, 0, 2)]
T_SPEC = 4


def run() -> dict:
    cfg, params, fam, _ = trained_model(32, 4)
    toks = mixed_request_batch(fam, seq_len=49, seed=7)   # (4, 49)
    spec_shape = (4, T_SPEC)
    base = teacher_forced_decode_ce(cfg, params, toks,
                                    XSharePolicy(mode="off"),
                                    spec_shape=spec_shape)
    base_otps = otps_model(cfg, base["activated"], 16)
    rows = [{"config": "baseline", **base, "otps_rel": 1.0,
             "ce_delta": 0.0}]
    for k0, m, m_r in CONFIGS:
        mode = "spec" if m_r > 0 else "batch"
        pol = XSharePolicy(mode=mode, k0=k0, m_l=m, m_r=m_r)
        r = teacher_forced_decode_ce(cfg, params, toks, pol,
                                     spec_shape=spec_shape
                                     if mode == "spec" else None)
        rows.append({"config": f"({k0},{m},{m_r})", **r,
                     "otps_rel": otps_model(cfg, r["activated"], 16)
                     / base_otps,
                     "ce_delta": r["ce"] - base["ce"]})
    best = next(r for r in rows if r["config"] == "(1,0,1)")
    return {"rows": rows, "mixed_gain_best": best["otps_rel"] - 1,
            "mixed_ce_delta_best": best["ce_delta"]}
