"""Benchmark driver — one module per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--only fig1,...]

Prints a ``name,us_per_call,derived`` CSV line per benchmark (us_per_call
= mean decode-step wall time where measured, else total bench wall), and
writes the full row data to benchmarks/results.json.
"""
from __future__ import annotations

import argparse
import inspect
import json
import os
import sys
import time
import traceback

BENCHES = ("fig1_activation", "fig3_overlap", "fig4_table3_tradeoff",
           "fig5_table4_spec", "table1_mixed", "table2_ep",
           "bs_ablation", "kernels_bench", "continuous_batching")

DERIVED_KEY = {
    "fig1_activation": ("worst_rel_err", "max |emp-formula|/formula"),
    "fig3_overlap": ("k5_ratio_spec_vs_cross",
                     "consecutive/cross overlap ratio @k=5"),
    "fig4_table3_tradeoff": ("reduction_at_(4,1)",
                             "activated-expert reduction @(m=4,k0=1)"),
    "fig5_table4_spec": ("speedup",
                         "scheduler-spec vs plain tokens/s (OTPS model)"),
    "table1_mixed": ("mixed_gain_best", "OTPS-model gain, mixed batch"),
    "table2_ep": ("ep_measured",
                  "measured EP scoreboard (shard_map, 8-dev mesh)"),
    "bs_ablation": ("reduction_bs4",
                    "activated-expert reduction @BS=4 (App B)"),
    "kernels_bench": ("bytes_at_quarter_activation",
                      "HBM bytes @25% activation vs full"),
    "continuous_batching": ("fused_speedup_bs8",
                            "fused-scan OTPS vs lockstep host loop @bs8"),
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None)
    ap.add_argument("--quick", action="store_true",
                    help="CI smoke mode (reduced shapes). Without "
                         "--only, runs the dispatch shootout + spec "
                         "scoreboard + EP scoreboard (persists "
                         "BENCH_dispatch.json / BENCH_spec.json / "
                         "BENCH_ep.json); with --only, runs exactly "
                         "the named benches in quick mode")
    args = ap.parse_args()
    names = BENCHES if not args.only else tuple(args.only.split(","))
    if args.quick and not args.only:
        names = ("kernels_bench", "fig5_table4_spec", "table2_ep")

    results = {}
    print("name,us_per_call,derived")
    failed = []
    for name in names:
        mod = __import__(f"benchmarks.{name}", fromlist=["run"])
        t0 = time.perf_counter()
        quick_ok = "quick" in inspect.signature(mod.run).parameters
        try:
            out = mod.run(quick=True) if args.quick and quick_ok \
                else mod.run()
        except Exception as e:  # noqa: BLE001
            failed.append(name)
            print(f"{name},ERROR,{e!r}")
            traceback.print_exc()
            continue
        wall_us = (time.perf_counter() - t0) * 1e6
        us = wall_us
        for row in out.get("rows", []):
            if isinstance(row, dict) and "wall_us_per_step" in row:
                us = row["wall_us_per_step"]
                break
        key, desc = DERIVED_KEY[name]
        derived = out.get(key)
        if isinstance(derived, float):
            derived = round(derived, 4)
        print(f"{name},{us:.1f},{derived}")
        results[name] = {"derived_desc": desc, "derived": derived, **out}

    path = os.path.join(os.path.dirname(__file__), "results.json")
    if (args.only or args.quick) and os.path.exists(path):  # merge partials
        merged = json.load(open(path))
        merged.update(results)
        results = merged
    with open(path, "w") as f:
        json.dump(results, f, indent=1, default=float)
    print(f"# wrote {path}", file=sys.stderr)
    if failed:
        raise SystemExit(f"benchmarks failed: {failed}")


if __name__ == "__main__":
    main()
