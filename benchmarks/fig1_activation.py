"""Figure 1 — expert activation vs batch size, empirical vs the closed
form E[N_a] = N(1-(1-k/N)^B), for the paper's two router geometries
(DeepSeek-R1: 256e/8k, GPT-OSS-120B: 128e/4k)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.metrics import expected_activated

GEOMETRIES = {"dsr1-256e8k": (256, 8), "gptoss-128e4k": (128, 4)}
BATCHES = (1, 2, 4, 8, 16, 32, 64, 128)


def run() -> dict:
    rows = []
    for name, (N, k) in GEOMETRIES.items():
        # router over random hidden states: a trained router's marginal
        # expert choice is near-uniform across a diverse batch, matching
        # the independence assumption behind the formula
        key = jax.random.PRNGKey(0)
        wg = jax.random.normal(key, (64, N)) * 0.5
        for B in BATCHES:
            acts = []
            for trial in range(20):
                x = jax.random.normal(
                    jax.random.PRNGKey(trial * 131 + B), (B, 64))
                logits = x @ wg
                idx = jax.lax.top_k(logits, k)[1]
                acts.append(len(np.unique(np.asarray(idx))))
            emp = float(np.mean(acts))
            formula = expected_activated(N, k, B)
            rows.append({"geometry": name, "N": N, "k": k, "B": B,
                         "empirical": emp, "formula": formula,
                         "rel_err": abs(emp - formula) / formula})
    worst = max(r["rel_err"] for r in rows)
    # paper's two calibration points: DSR1 B=8 -> ~57, B=32 -> ~163
    b8 = [r for r in rows if r["geometry"] == "dsr1-256e8k"
          and r["B"] == 8][0]
    b32 = [r for r in rows if r["geometry"] == "dsr1-256e8k"
           and r["B"] == 32][0]
    return {"rows": rows, "worst_rel_err": worst,
            "dsr1_b8": b8["empirical"], "dsr1_b32": b32["empirical"],
            "paper_b8": 57, "paper_b32": 163}
