"""Figure 4 / Table 3 / Figure 7 — Algorithm 2 (batch-aware selection)
sweep over (budget m_l, warm-up k0) at batch size 16, no speculation:
decode-time accuracy proxy (teacher-forced CE delta vs baseline),
activated experts, gating mass, and OTPS (memory-bound byte model +
relative gain) per configuration.

Paper budgets are for E=128; we run E=32 and scale budgets by E/4 so the
relative sparsity matches Table 3's (m_l, k0) grid.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import (DATASETS, eval_tokens, otps_model,
                               teacher_forced_decode_ce, trained_model)
from repro.configs.base import XSharePolicy

# Table 3 grid (m_l scaled /4 for E=32 vs paper's E=128)
CONFIGS = [(0, 1), (3, 1), (4, 1), (6, 1), (8, 1), (0, 2), (3, 2),
           (6, 0)]
BATCH = 16


def run() -> dict:
    cfg, params, fam, losses = trained_model(32, 4)
    toks = eval_tokens(fam, DATASETS, batch_per=BATCH // 4, seq=48)
    base = teacher_forced_decode_ce(cfg, params, toks,
                                    XSharePolicy(mode="off"))
    base_otps = otps_model(cfg, base["activated"], BATCH)
    rows = [{"config": "baseline", "m_l": None, "k0": None, **base,
             "otps_rel": 1.0, "ce_delta": 0.0}]
    for m_l, k0 in CONFIGS:
        pol = XSharePolicy(mode="batch", k0=k0, m_l=m_l)
        r = teacher_forced_decode_ce(cfg, params, toks, pol)
        otps = otps_model(cfg, r["activated"], BATCH)
        rows.append({"config": f"({m_l},{k0})", "m_l": m_l, "k0": k0,
                     **r, "otps_rel": otps / base_otps,
                     "ce_delta": r["ce"] - base["ce"]})
    # paper-claim checks: the (m_l=16,k0=1)-equivalent config (4,1)
    # gains throughput with small quality loss; (0,1) is fastest but
    # degrades most (Sec 6.1)
    c41 = next(r for r in rows if r["config"] == "(4,1)")
    c01 = next(r for r in rows if r["config"] == "(0,1)")
    return {
        "rows": rows,
        "train_loss_first_last": (losses[0], losses[-1]),
        "reduction_at_(4,1)": 1 - c41["activated"] / base["activated"],
        "otps_gain_at_(4,1)": c41["otps_rel"] - 1,
        "ce_delta_at_(4,1)": c41["ce_delta"],
        "otps_gain_at_(0,1)": c01["otps_rel"] - 1,
        "ce_delta_at_(0,1)": c01["ce_delta"],
    }
