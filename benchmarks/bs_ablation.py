"""Appendix B batch-size ablation: Algorithm 2 at a fixed relative
budget (m_l = E/8, k0 = 1) across decode batch sizes — the
activated-expert reduction and its OTPS-model gain shrink as the
warm-up union saturates the expert set (the effect quantified in §Perf
iteration 1 at production batch 128)."""
from __future__ import annotations

import numpy as np

from benchmarks.common import (DATASETS, eval_tokens, otps_model,
                               teacher_forced_decode_ce, trained_model)
from repro.configs.base import XSharePolicy

BATCHES = (4, 8, 16, 32)


def run() -> dict:
    cfg, params, fam, _ = trained_model(32, 4)
    rows = []
    for bs in BATCHES:
        toks = eval_tokens(fam, DATASETS, batch_per=max(1, bs // 4),
                           seq=40)[:bs]
        base = teacher_forced_decode_ce(cfg, params, toks,
                                        XSharePolicy(mode="off"))
        pol = XSharePolicy(mode="batch", k0=1,
                           m_l=cfg.moe.num_experts // 8)
        r = teacher_forced_decode_ce(cfg, params, toks, pol)
        gain = otps_model(cfg, r["activated"], bs) \
            / otps_model(cfg, base["activated"], bs) - 1
        rows.append({"batch": bs,
                     "base_activated": base["activated"],
                     "xshare_activated": r["activated"],
                     "reduction": 1 - r["activated"] / base["activated"],
                     "otps_gain": gain,
                     "ce_delta": r["ce"] - base["ce"],
                     "wall_us_per_step": r["wall_us_per_step"]})
    return {"rows": rows,
            "reduction_bs4": rows[0]["reduction"],
            "reduction_bs32": rows[-1]["reduction"]}
