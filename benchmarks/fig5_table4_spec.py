"""Figure 5 / Table 4 / Figure 8 — speculative-decoding-aware selection
(Algorithm 4) vs flat batch selection (Algorithm 2) at BS=4, speculation
length 3: the verify step processes (b=4, t=4) token blocks, and the
hierarchical per-request budgets exploit intra-request correlation.

Configs follow Table 4's (k0, m, m_r) grid (budgets scaled /4 for E=32).
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import (DATASETS, eval_tokens, otps_model,
                               teacher_forced_decode_ce, trained_model)
from repro.configs.base import XSharePolicy

# (k0, m, m_r) — Table 4 grid scaled /4
CONFIGS = [(0, 4, 1), (1, 0, 1), (1, 0, 2), (2, 0, 1), (1, 6, 0),
           (1, 8, 0), (2, 3, 0), (0, 0, 2)]
B_REQ = 4
T_SPEC = 4      # 1 + L_s with L_s = 3


def run() -> dict:
    cfg, params, fam, _ = trained_model(32, 4)
    toks = eval_tokens(fam, DATASETS, batch_per=1, seq=49)  # b=4 requests
    spec_shape = (B_REQ, T_SPEC)
    base = teacher_forced_decode_ce(cfg, params, toks,
                                    XSharePolicy(mode="off"),
                                    spec_shape=spec_shape)
    base_otps = otps_model(cfg, base["activated"], B_REQ * T_SPEC)
    rows = [{"config": "baseline", **base, "otps_rel": 1.0,
             "ce_delta": 0.0, "mode": "off"}]
    for k0, m, m_r in CONFIGS:
        mode = "spec" if m_r > 0 else "batch"
        pol = XSharePolicy(mode=mode, k0=k0, m_l=m, m_r=m_r)
        r = teacher_forced_decode_ce(cfg, params, toks, pol,
                                     spec_shape=spec_shape
                                     if mode == "spec" else None)
        otps = otps_model(cfg, r["activated"], B_REQ * T_SPEC)
        rows.append({"config": f"({k0},{m},{m_r})", **r,
                     "otps_rel": otps / base_otps,
                     "ce_delta": r["ce"] - base["ce"], "mode": mode})
    # paper claims: (1,0,4)-equivalent Pareto-optimal; missing warm-up
    # (0,16,4)-equivalent degrades accuracy hard (Sec 6.2)
    best = next(r for r in rows if r["config"] == "(1,0,1)")
    nowarm = next(r for r in rows if r["config"] == "(0,4,1)")
    return {"rows": rows,
            "spec_gain_best": best["otps_rel"] - 1,
            "spec_ce_delta_best": best["ce_delta"],
            "nowarm_ce_delta": nowarm["ce_delta"]}
