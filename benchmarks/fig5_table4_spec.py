"""Figure 5 / Table 4 / Figure 8 — speculative decoding as a scheduler
subsystem, scored on heterogeneous traffic.

Three questions, answered with live serving runs (not static grids):

1. **Throughput** — does the scheduler-integrated draft-then-verify
   path (serving/spec_scheduler.py) beat plain continuous decoding on
   tokens/s? Scored two ways: measured CPU wall clock, and the
   memory-bound OTPS byte model (decode step time ~ HBM bytes of
   weights touched — the paper's premise), which is deterministic and
   is the contract `check_bench_schema.py` enforces.
2. **Losslessness** — greedy scheduler-spec output must be token-exact
   vs the lockstep spec reference AND vs plain greedy, including a
   mixed spec+plain batch sharing one running batch.
3. **Selection** — hierarchical, correlation-aware Algorithm-4
   selection (mode="spec" with per-request budgets + batch top-up +
   cross-pass gate priors) must activate fewer experts than naive
   per-request top-k at the verify shapes, at comparable acceptance.

The draft is a separately *trained* dense model (benchmarks/common.py
``trained_draft``) — agreement with the MoE target comes from shared
training data, not shared weights, so the acceptance rate is a real
measurement. Results persist to BENCH_spec.json at the repo root.
"""
from __future__ import annotations

import json
import os

import numpy as np

from benchmarks.common import (DATASETS, eval_tokens, param_bytes,
                               trained_draft, trained_model)
from repro.configs.base import XSharePolicy
from repro.kernels.ops import moe_step_bytes
from repro.serving import Engine

BENCH_PATH = os.path.join(os.path.dirname(__file__), os.pardir,
                          "BENCH_spec.json")

# Algorithm 4: warm-up union + per-request budget + batch top-up, with
# the cross-pass correlation prior (corr) feeding scheduler gate
# histograms back into selection.
HIER = XSharePolicy(mode="spec", k0=1, m_l=2, m_r=1, corr=1.0)
# Naive reference: every request independently keeps its own top-k
# (k = top_k of the model), no hierarchy, no correlation prior.
NAIVE = XSharePolicy(mode="spec", k0=0, m_l=0, m_r=4, corr=0.0)


def _exact(a: np.ndarray, b: np.ndarray) -> bool:
    return bool(np.array_equal(np.asarray(a), np.asarray(b)))


def run(quick: bool = False) -> dict:
    cfg, params, fam, _ = trained_model(32, 4)
    dcfg, dparams = trained_draft()
    B, seq, Ls = 8, 16, 3
    max_new = 24 if quick else 48
    prompts = eval_tokens(fam, DATASETS, batch_per=B // len(DATASETS),
                          seq=seq)
    kw = dict(cache_len=seq + max_new + Ls + 8)

    plain_eng = Engine(cfg, params, **kw)
    spec_eng = Engine(cfg, params, draft=(dcfg, dparams), spec_len=Ls,
                      **kw)
    # warm both compiled paths so the timed runs measure steady state
    plain_eng.generate(prompts, 4)
    spec_eng.generate(prompts, 4)

    plain_toks, plain_st = plain_eng.generate(prompts, max_new)
    spec_toks, spec_st = spec_eng.generate(prompts, max_new)
    lock_toks, lock_st = spec_eng.generate(prompts, max_new,
                                           lockstep=True)
    token_exact_vs_plain = _exact(plain_toks, spec_toks)
    token_exact_vs_lockstep = _exact(lock_toks, spec_toks)

    # mixed traffic: spec and plain requests share one running batch
    # (fewer slots than requests, so eviction/readmission is exercised)
    sched = spec_eng.make_scheduler(num_slots=B // 2, invariants=True)
    for b in range(B):
        sched.submit(prompts[b], max_new, spec=(b % 2 == 0))
    states = sched.run()
    mixed_exact = all(
        _exact(np.asarray(st.tokens[:max_new]), plain_toks[b])
        for b, st in enumerate(states))

    # --- OTPS byte model (memory-bound regime) ------------------------
    E, k, L = cfg.moe.num_experts, cfg.moe.top_k, cfg.num_layers
    step_bytes = moe_step_bytes(min(E, B * k), cfg.d_model,
                                cfg.moe.d_ff_expert, tokens=B,
                                top_k=k) * L
    verify_bytes = moe_step_bytes(min(E, B * (Ls + 1) * k), cfg.d_model,
                                  cfg.moe.d_ff_expert,
                                  tokens=B * (Ls + 1), top_k=k) * L
    # the draft scan always runs spec_len+1 dense steps per round
    round_bytes = verify_bytes + (Ls + 1) * param_bytes(dparams)
    rounds = max(spec_st.steps, 1)
    tokens_per_round = spec_st.new_tokens / rounds
    otps_baseline = 1e9 * B / step_bytes
    otps_spec = 1e9 * tokens_per_round / round_bytes
    speedup = otps_spec / otps_baseline
    speedup_wall = spec_st.otps / max(plain_st.otps, 1e-9)

    # --- hierarchical vs naive per-request top-k selection, live ------
    hier_eng = Engine(cfg, params, policy=HIER,
                      draft=(dcfg, dparams), spec_len=Ls, **kw)
    naive_eng = Engine(cfg, params, policy=NAIVE,
                       draft=(dcfg, dparams), spec_len=Ls, **kw)
    _, hier_st = hier_eng.generate(prompts, max_new)
    _, naive_st = naive_eng.generate(prompts, max_new)
    act_hier = hier_st.mean_aux("activated_experts")
    act_naive = naive_st.mean_aux("activated_experts")

    rows = [
        {"config": "plain", "otps_model": otps_baseline,
         "wall_otps": plain_st.otps, "acceptance": 0.0},
        {"config": "sched-spec", "otps_model": otps_spec,
         "wall_otps": spec_st.otps,
         "acceptance": spec_st.acceptance_rate,
         "tokens_per_round": tokens_per_round},
        {"config": "lockstep-spec", "wall_otps": lock_st.otps,
         "acceptance": lock_st.acceptance_rate},
        {"config": "hier-(1,2,1)", "activated": act_hier,
         "acceptance": hier_st.acceptance_rate},
        {"config": "naive-(0,0,4)", "activated": act_naive,
         "acceptance": naive_st.acceptance_rate},
    ]
    out = {
        "rows": rows,
        "speedup": speedup,
        "speedup_wall": speedup_wall,
        "acceptance_rate": spec_st.acceptance_rate,
        "drafted": spec_st.drafted,
        "accepted": spec_st.accepted,
        "rounds": rounds,
        "tokens_per_round": tokens_per_round,
        "otps_spec": otps_spec,
        "otps_baseline": otps_baseline,
        "spec_budget_exhausted": spec_st.spec_budget_exhausted,
        "token_exact_vs_plain": token_exact_vs_plain,
        "token_exact_vs_lockstep": token_exact_vs_lockstep,
        "token_exact_mixed": mixed_exact,
        "activated_hier": act_hier,
        "activated_naive": act_naive,
        "activated_ratio": act_hier / max(act_naive, 1e-9),
        "acceptance_hier": hier_st.acceptance_rate,
        "acceptance_naive": naive_st.acceptance_rate,
    }
    with open(BENCH_PATH, "w") as f:
        json.dump({"spec": out}, f, indent=1, default=float)
    return out
