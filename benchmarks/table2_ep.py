"""Table 2 — expert-parallel deployment (DeepSeek-R1 geometry: 256
routed experts, top-8, 1 shared expert, 8 device groups): baseline
routing vs Algorithm 6 (k0=1, m_g=5): total activated experts, peak
per-group load (the bottleneck-GPU metric), accuracy proxy.

Per-shard load is measured two ways since the sorted-dispatch landing:
``max_load`` counts activated *experts* on the busiest group (the
paper's metric), and ``max_shard_tokens`` counts the real token
segments landing there — what the bottleneck device actually computes
under sorted grouped-GEMM dispatch, vs the E/G * C rows the
capacity-padded einsum dispatch always pays regardless of routing."""
from __future__ import annotations

import numpy as np

from benchmarks.common import (DATASETS, eval_tokens,
                               teacher_forced_decode_ce, trained_model)
from repro.configs.base import XSharePolicy

G = 8
E, K = 256, 8


def run() -> dict:
    cfg, params, fam, _ = trained_model(E, K)
    rows = []
    claims = {}
    for bs in (8, 16):
        toks = eval_tokens(fam, DATASETS, batch_per=bs // 4, seq=40)
        base = teacher_forced_decode_ce(
            cfg, params, toks, XSharePolicy(mode="off", num_groups=G))
        alg6 = teacher_forced_decode_ce(
            cfg, params, toks,
            XSharePolicy(mode="ep", k0=1, m_g=5, num_groups=G))
        # drop-free capacity padding would put t*k/G... no: E/G * C rows
        # on EVERY shard (C = per-expert capacity ~ batch size when
        # drop-free); the real bottleneck shard holds its segments only
        padded_rows_per_shard = (E // G) * bs
        rows.append({"batch": bs, "method": "baseline", **base})
        rows.append({"batch": bs, "method": "alg6(1,5)", **alg6})
        claims[f"bs{bs}"] = {
            "experts_drop": 1 - alg6["activated"] / base["activated"],
            "peak_load_ratio": base["max_load"] / max(alg6["max_load"],
                                                      1e-9),
            "peak_shard_tokens_ratio":
                base["max_shard_tokens"]
                / max(alg6["max_shard_tokens"], 1e-9),
            "real_vs_padded_shard_rows":
                alg6["max_shard_tokens"] / padded_rows_per_shard,
            "ce_delta": alg6["ce"] - base["ce"],
            "max_load_bound_ok": alg6["max_load"] <= 5 + 1e-6,
        }
    return {"rows": rows, **claims}
