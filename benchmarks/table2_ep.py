"""Table 2 — expert-parallel deployment (DeepSeek-R1 geometry: 256
routed experts, top-8, 8 device groups), in two layers:

* the paper-metric simulation (full mode): baseline routing vs
  Algorithm 6 (k0=1, m_g=5) under teacher-forced decode — activated
  experts, peak per-group load, accuracy proxy (the original Table 2);

* a MEASURED-EXECUTION scoreboard: the shard_map EP executor
  (ep/executor.py) actually runs baseline routing, Algorithm 6, and
  Algorithm 6 + hot-expert replication on an 8-device emulated mesh in
  a subprocess (XLA_FLAGS device-count forcing must precede jax
  import, hence the fork), at decode shape (B=16 requests, one token
  each) and at the speculative verify shape B x (1 + L_s). Scored per
  shard: rows the grouped GEMM actually executed (occupied tiles *
  block_t — at decode sizes this is dominated by active experts per
  shard, the quantity Algorithm 6 bounds), real segment rows, and
  all-to-all bytes on the wire. Every executed step is checked
  token-exact against the single-device sorted reference.

Routing comes from the trained router (layer 0) over real token
embeddings of the heterogeneous eval sets — trained expert affinities,
not synthetic skew. Results persist to BENCH_ep.json at the repo root
(contract: benchmarks/check_bench_schema.py), wired into both CI jobs
via ``benchmarks.run --quick``.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
import tempfile

import numpy as np

G = 8                  # device groups == EP shards
E, K = 256, 8
S = 8
BLOCK_T = 8            # tile grid of the measured grouped GEMM
SPEC_LS = 3            # verify shape: B x (1 + L_s)
REPLICATE_HOT = 1      # replicate the hottest expert...
MAX_REPLICAS = 2       # ...two ways (decode segments are tile-sized:
                       # heavy replication just mints padding tiles)

BENCH_PATH = os.path.join(os.path.dirname(__file__), os.pardir,
                          "BENCH_ep.json")


def _routing_traces(cfg, params, fam, *, bs: int, steps: int):
    """Per-step routing decisions from the trained layer-0 router over
    real token embeddings: decode shape (bs, 1 token) and spec verify
    shape (bs, 1 + L_s), for baseline and Algorithm-6 policies."""
    import jax
    import jax.numpy as jnp

    from benchmarks.common import DATASETS, eval_tokens
    from repro.configs.base import XSharePolicy
    from repro.models.model import embed_tokens
    from repro.models.moe import route

    toks = eval_tokens(fam, DATASETS, batch_per=bs // 4, seq=40)
    emb = embed_tokens(cfg, params, jnp.asarray(toks))
    layer = jax.tree_util.tree_map(lambda a: a[0],
                                   params["layers"]["moe"])
    pol_off = XSharePolicy(mode="off", num_groups=G)
    pol_x = XSharePolicy(mode="ep", k0=1, m_g=4, num_groups=G)
    out = {}
    for shape, width in (("dec", 1), ("spec", 1 + SPEC_LS)):
        xs, tr = [], {"off": ([], []), "alg6": ([], [])}
        hists = []
        for step in range(steps):
            pos = 8 + (step * width) % (40 - 8 - width)
            x = emb[:, pos:pos + width].reshape(-1, cfg.d_model)
            xs.append(np.asarray(x))
            for name, pol in (("off", pol_off), ("alg6", pol_x)):
                idx, w, _, _ = route(layer, x, cfg.moe, pol)
                tr[name][0].append(np.asarray(idx))
                tr[name][1].append(np.asarray(w))
            counts = np.zeros(E, np.int64)
            np.add.at(counts,
                      tr["alg6"][0][-1].reshape(-1).clip(0),
                      tr["alg6"][1][-1].reshape(-1) != 0)
            hists.append(counts)
        out[shape] = {
            "x": np.stack(xs).astype(np.float32),
            "idx_off": np.stack(tr["off"][0]).astype(np.int32),
            "w_off": np.stack(tr["off"][1]).astype(np.float32),
            "idx_x": np.stack(tr["alg6"][0]).astype(np.int32),
            "w_x": np.stack(tr["alg6"][1]).astype(np.float32),
            "hist": np.stack(hists).astype(np.float64),
        }
    return out


def _measure_in_subprocess(cfg, params, traces) -> dict:
    """Fork a fresh interpreter with 8 emulated devices and run the EP
    executor over the saved routing traces."""
    moe = params["layers"]["moe"]
    payload = {"w1": np.asarray(moe["w1"][0], np.float32),
               "w3": np.asarray(moe["w3"][0], np.float32),
               "w2": np.asarray(moe["w2"][0], np.float32)}
    for shape, tr in traces.items():
        for k, v in tr.items():
            payload[f"{shape}_{k}"] = v
    with tempfile.TemporaryDirectory() as td:
        inp = os.path.join(td, "traces.npz")
        outp = os.path.join(td, "measured.json")
        np.savez(inp, **payload)
        root = os.path.join(os.path.dirname(__file__), os.pardir)
        env = {
            "PATH": os.environ.get("PATH", "/usr/bin:/bin"),
            "HOME": os.environ.get("HOME", "/root"),
            "PYTHONPATH": os.path.join(root, "src") + os.pathsep + root,
            "XLA_FLAGS": f"--xla_force_host_platform_device_count={S}",
            # CPU explicitly: device-count forcing is a host-platform
            # feature, and on boxes with an accelerator plugin (libtpu)
            # the child would otherwise block on the parent's device
            # lockfile forever
            "JAX_PLATFORMS": "cpu",
        }
        res = subprocess.run(
            [sys.executable, "-m", "benchmarks.table2_ep",
             "--measure", inp, outp],
            capture_output=True, text=True, timeout=1800, env=env,
            cwd=root)
        if res.returncode != 0:
            raise RuntimeError(
                f"EP measurement subprocess failed:\n{res.stderr[-3000:]}")
        with open(outp) as f:
            return json.load(f)


def _measure(inp: str, outp: str) -> None:
    """Subprocess body: real shard_map execution on the 8-device mesh.

    Three executors per shape — baseline routing on the standard
    contiguous layout, Algorithm-6 routing on histogram-driven LPT
    placement, and the same plus hot-expert replication with
    between-step hysteresis rebalancing. Every step's output is checked
    exact against the single-device sorted reference.
    """
    import jax  # noqa: F401  (imports under the XLA_FLAGS env)
    import jax.numpy as jnp

    from repro.ep import EPExecutor, contiguous_placement, plan_placement
    from repro.models.dispatch import sorted_expert_ffn
    from repro.sharding import make_ep_mesh

    data = np.load(inp)
    w1, w3, w2 = (jnp.asarray(data[k]) for k in ("w1", "w3", "w2"))
    mesh = make_ep_mesh(S)
    out = {}
    for shape in ("dec", "spec"):
        hist = data[f"{shape}_hist"]
        execs = {
            "off": EPExecutor(mesh, contiguous_placement(E, S),
                              block_t=BLOCK_T),
            "alg6": EPExecutor(mesh, plan_placement(hist[0], S),
                               block_t=BLOCK_T),
            "alg6_rep": EPExecutor(
                mesh,
                plan_placement(hist[0], S, replicate_hot=REPLICATE_HOT,
                               max_replicas=MAX_REPLICAS),
                block_t=BLOCK_T, replicate_hot=REPLICATE_HOT,
                max_replicas=MAX_REPLICAS),
        }
        rec = {m: {"tile_peak": [], "row_peak": [], "a2a": []}
               for m in execs}
        exact = True
        steps = data[f"{shape}_x"].shape[0]
        for t in range(steps):
            x = jnp.asarray(data[f"{shape}_x"][t])
            for m, ex in execs.items():
                side = "off" if m == "off" else "x"
                idx = jnp.asarray(data[f"{shape}_idx_{side}"][t])
                w = jnp.asarray(data[f"{shape}_w_{side}"][t])
                if m != "off" and t > 0:
                    # between-step rebalance from the fresh histogram
                    # (hysteresis inside); replication is the only
                    # difference between alg6 and alg6_rep
                    ex.update_placement(hist[t])
                y, st = ex(x, w1, w3, w2, idx, w)
                ref = sorted_expert_ffn(x, w1, w3, w2, idx, w,
                                        block_t=BLOCK_T)
                exact &= bool(np.array_equal(np.asarray(y),
                                             np.asarray(ref)))
                rec[m]["tile_peak"].append(st.peak_tile_rows)
                rec[m]["row_peak"].append(st.peak_rows)
                rec[m]["a2a"].append(st.total_a2a_bytes)
        rep = execs["alg6_rep"]
        out[shape] = {
            "steps": steps,
            "exact_vs_single_device": exact,
            "per_method": {m: {k: [int(v) for v in vs]
                               for k, vs in r.items()}
                           for m, r in rec.items()},
            "rebalances": rep.rebalances,
            "rebalances_skipped": rep.rebalances_skipped,
            "replication_factor": float(rep.placement.replication_factor),
            "max_rows": int(execs["off"]._resolve_max_rows(
                None, None, None,
                data[f"{shape}_x"].shape[1] // S * K)),
        }
    with open(outp, "w") as f:
        json.dump(out, f, indent=1)


def _ratios(shape_rec: dict) -> dict:
    pm = shape_rec["per_method"]
    off_t = np.asarray(pm["off"]["tile_peak"], float)
    off_r = np.asarray(pm["off"]["row_peak"], float)
    res = {}
    for m in ("alg6", "alg6_rep"):
        mt = np.maximum(np.asarray(pm[m]["tile_peak"], float), 1.0)
        res[f"peak_rows_ratio_{m}"] = float((off_t / mt).mean())
        res[f"peak_rows_ratio_{m}_min"] = float((off_t / mt).min())
        res[f"peak_real_rows_ratio_{m}"] = float(
            (off_r / np.maximum(np.asarray(pm[m]["row_peak"], float),
                                1.0)).mean())
    res["a2a_bytes_baseline"] = int(np.mean(pm["off"]["a2a"]))
    res["a2a_bytes_xshare"] = int(np.mean(pm["alg6_rep"]["a2a"]))
    return res


def run(quick: bool = False) -> dict:
    from benchmarks.common import (DATASETS, eval_tokens,
                                   teacher_forced_decode_ce,
                                   trained_model)
    from repro.configs.base import XSharePolicy

    cfg, params, fam, _ = trained_model(E, K, steps=60 if quick else 150)
    rows = []
    claims = {}
    if not quick:
        # the original simulated Table 2 (paper metric: activated
        # experts + peak per-group load + CE proxy)
        for bs in (8, 16):
            toks = eval_tokens(fam, DATASETS, batch_per=bs // 4, seq=40)
            base = teacher_forced_decode_ce(
                cfg, params, toks, XSharePolicy(mode="off", num_groups=G))
            alg6 = teacher_forced_decode_ce(
                cfg, params, toks,
                XSharePolicy(mode="ep", k0=1, m_g=5, num_groups=G))
            padded_rows_per_shard = (E // G) * bs
            rows.append({"batch": bs, "method": "baseline", **base})
            rows.append({"batch": bs, "method": "alg6(1,5)", **alg6})
            claims[f"bs{bs}"] = {
                "experts_drop": 1 - alg6["activated"] / base["activated"],
                "peak_load_ratio": base["max_load"]
                / max(alg6["max_load"], 1e-9),
                "peak_shard_tokens_ratio":
                    base["max_shard_tokens"]
                    / max(alg6["max_shard_tokens"], 1e-9),
                "real_vs_padded_shard_rows":
                    alg6["max_shard_tokens"] / padded_rows_per_shard,
                "ce_delta": alg6["ce"] - base["ce"],
                "max_load_bound_ok": alg6["max_load"] <= 5 + 1e-6,
            }

    # ---- measured EP execution (8-device mesh, subprocess) -----------
    bs = 16
    steps = 4 if quick else 10
    traces = _routing_traces(cfg, params, fam, bs=bs, steps=steps)
    measured = _measure_in_subprocess(cfg, params, traces)
    dec, spec = measured["dec"], measured["spec"]
    ep = {
        "batch": bs,
        "steps": dec["steps"],
        "block_t": BLOCK_T,
        "exact_vs_single_device":
            dec["exact_vs_single_device"] and
            spec["exact_vs_single_device"],
        # headline: Algorithm 6 + replication vs baseline routing,
        # measured peak-shard executed rows at decode, mean over steps
        "peak_rows_ratio": _ratios(dec)["peak_rows_ratio_alg6_rep"],
        **_ratios(dec),
        "replication_factor": dec["replication_factor"],
        "rebalances": dec["rebalances"],
        "rebalances_skipped": dec["rebalances_skipped"],
        "spec_shape": [bs, 1 + SPEC_LS],
        "spec_peak_rows_ratio":
            _ratios(spec)["peak_rows_ratio_alg6_rep"],
        "spec_exact_vs_single_device": spec["exact_vs_single_device"],
        "spec_a2a_bytes_xshare": _ratios(spec)["a2a_bytes_xshare"],
    }
    with open(BENCH_PATH, "w") as f:
        json.dump({"ep": ep, "measured_detail": measured}, f, indent=1,
                  default=float)
    claims["ep_measured"] = ep
    if quick:
        claims["bs16"] = {"quick": True, **ep}
    return {"rows": rows, **claims}


if __name__ == "__main__":
    if len(sys.argv) == 4 and sys.argv[1] == "--measure":
        _measure(sys.argv[2], sys.argv[3])
    else:
        print(json.dumps(run(quick="--quick" in sys.argv), indent=1,
                         default=float))
