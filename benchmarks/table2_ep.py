"""Table 2 — expert-parallel deployment (DeepSeek-R1 geometry: 256
routed experts, top-8, 1 shared expert, 8 device groups): baseline
routing vs Algorithm 6 (k0=1, m_g=5): total activated experts, peak
per-group load (the bottleneck-GPU metric), accuracy proxy."""
from __future__ import annotations

import numpy as np

from benchmarks.common import (DATASETS, eval_tokens,
                               teacher_forced_decode_ce, trained_model)
from repro.configs.base import XSharePolicy

G = 8


def run() -> dict:
    cfg, params, fam, _ = trained_model(256, 8)
    rows = []
    claims = {}
    for bs in (8, 16):
        toks = eval_tokens(fam, DATASETS, batch_per=bs // 4, seq=40)
        base = teacher_forced_decode_ce(
            cfg, params, toks, XSharePolicy(mode="off", num_groups=G))
        alg6 = teacher_forced_decode_ce(
            cfg, params, toks,
            XSharePolicy(mode="ep", k0=1, m_g=5, num_groups=G))
        rows.append({"batch": bs, "method": "baseline", **base})
        rows.append({"batch": bs, "method": "alg6(1,5)", **alg6})
        claims[f"bs{bs}"] = {
            "experts_drop": 1 - alg6["activated"] / base["activated"],
            "peak_load_ratio": base["max_load"] / max(alg6["max_load"],
                                                      1e-9),
            "ce_delta": alg6["ce"] - base["ce"],
            "max_load_bound_ok": alg6["max_load"] <= 5 + 1e-6,
        }
    return {"rows": rows, **claims}
