"""Continuous-batching serving benchmark — the system the paper's batch
premise needs.

Three measurements over the trained bench-moe model:

  1. Fused-decode speedup: all requests at t=0, batch 8 — the fused
     on-device N-token scan (serving/step.py) vs. the seed's per-token
     host loop (one dispatch + one device->host sync per token). Both
     produce identical tokens; only the serving mechanics differ.

  2. Arrival-process traffic: Poisson arrivals of requests drawn from
     heterogeneous synthetic datasets, served by the continuous
     scheduler with FIFO admission. Reports OTPS plus p50/p99
     end-to-end latency — quantities the lockstep engine cannot even
     express (it has no notion of a request arriving mid-decode).

  3. Admission-policy ablation: the same traffic under FIFO vs.
     XShare-affinity admission (batch composition by gate-histogram
     overlap), comparing activated experts per layer-step — the paper's
     correlation-aware selection lifted to the scheduling layer.
"""
from __future__ import annotations

import time
from typing import Dict, List

import numpy as np

from benchmarks.common import DATASETS, trained_model
from repro.serving import Engine

BATCH = 8
MAX_NEW = 192
PROMPT_LEN = 32
DECODE_CHUNK = 32
TRAFFIC_REQUESTS = 24
TRAFFIC_MAX_NEW = 48
TRAFFIC_SLOTS = 4
TRAFFIC_CHUNK = 16            # shorter chunks: admission every 16 tokens
TRAFFIC_RATE_HZ = 40.0        # Poisson arrival rate (offered load)


def _prompts(fam, n: int, seed: int) -> List[np.ndarray]:
    """n prompts cycling over the heterogeneous dataset family."""
    rng = np.random.default_rng(seed)
    names = list(fam)
    return [fam[names[i % len(names)]].sample(rng, 1, PROMPT_LEN)[0]
            for i in range(n)]


def _traffic_run(eng: Engine, prompts, arrivals, admission: str) -> Dict:
    sched = eng.make_scheduler(num_slots=TRAFFIC_SLOTS,
                               admission=admission,
                               decode_chunk=TRAFFIC_CHUNK)
    for p, t in zip(prompts, arrivals):
        sched.submit(p, TRAFFIC_MAX_NEW, arrival_s=t)
    t0 = time.perf_counter()
    states = sched.run()
    wall = time.perf_counter() - t0
    lat = np.array([s.latency_s for s in states])
    acts = [float(np.mean(a["activated_experts"]))
            for a in sched.step_aux]
    toks = sum(len(s.tokens) for s in states)
    return {
        "admission": admission,
        "otps": toks / wall,
        "p50_latency_s": float(np.percentile(lat, 50)),
        "p99_latency_s": float(np.percentile(lat, 99)),
        "mean_ttft_s": float(np.mean([s.ttft_s for s in states])),
        "activated_experts": float(np.mean(acts)),
        "decode_steps": sched.total_steps,
    }


def run() -> dict:
    cfg, params, fam, _ = trained_model(32, 4)
    eng = Engine(cfg, params, cache_len=PROMPT_LEN + MAX_NEW + 8,
                 decode_chunk=DECODE_CHUNK)
    rng = np.random.default_rng(0)
    batch = np.stack(_prompts(fam, BATCH, seed=1))

    # -- 1. fused continuous vs. seed per-token host loop, all at t=0 ------
    eng.generate(batch, 8, lockstep=True)          # compile both paths
    eng.generate(batch, 8)
    lock_otps, cont_otps, exact = [], [], True
    for _ in range(4):               # interleaved: noise hits both sides
        toks_l, st_l = eng.generate(batch, MAX_NEW, lockstep=True)
        toks_c, st_c = eng.generate(batch, MAX_NEW)
        exact &= bool(np.array_equal(toks_l, toks_c))
        lock_otps.append(st_l.otps)
        cont_otps.append(st_c.otps)
    lockstep_best = max(lock_otps)
    fused_best = max(cont_otps)
    speedup = fused_best / lockstep_best
    rows = [{
        "config": f"lockstep bs{BATCH}", "otps": lockstep_best,
        "wall_us_per_step": 1e6 / lockstep_best * BATCH,
    }, {
        "config": f"fused bs{BATCH} chunk{DECODE_CHUNK}",
        "otps": fused_best,
        "wall_us_per_step": 1e6 / fused_best * BATCH,
        "token_exact_vs_lockstep": exact,
    }]

    # -- 2/3. Poisson traffic, FIFO vs. affinity admission -----------------
    # each policy runs twice and the SECOND run is reported: staggered
    # admission hits jit shapes (partial-group prefills, insert) the
    # bulk path never compiles, and they must not be charged to
    # whichever policy happens to run first
    prompts = _prompts(fam, TRAFFIC_REQUESTS, seed=2)
    arrivals = np.cumsum(
        rng.exponential(1.0 / TRAFFIC_RATE_HZ, TRAFFIC_REQUESTS))
    fifo = [_traffic_run(eng, prompts, arrivals, "fcfs")
            for _ in range(2)][-1]
    aff = [_traffic_run(eng, prompts, arrivals, "affinity")
           for _ in range(2)][-1]
    rows += [fifo, aff]

    act_delta = fifo["activated_experts"] - aff["activated_experts"]
    return {
        "rows": rows,
        "fused_speedup_bs8": speedup,
        "token_exact": exact,
        "affinity_activated_delta": act_delta,
        "affinity_activated_rel": act_delta
        / max(fifo["activated_experts"], 1e-9),
    }


if __name__ == "__main__":
    out = run()
    for r in out["rows"]:
        print(r)
    print({k: v for k, v in out.items() if k != "rows"})
