"""Continuous-batching serving benchmark — the system the paper's batch
premise needs.

Three measurements over the trained bench-moe model:

  1. Fused-decode speedup: all requests at t=0, batch 8 — the fused
     on-device N-token scan (serving/step.py) vs. the seed's per-token
     host loop (one dispatch + one device->host sync per token). Both
     produce identical tokens; only the serving mechanics differ.

  2. Arrival-process traffic: Poisson arrivals of requests drawn from
     heterogeneous synthetic datasets, served by the continuous
     scheduler with FIFO admission. Reports OTPS plus p50/p99
     end-to-end latency — quantities the lockstep engine cannot even
     express (it has no notion of a request arriving mid-decode).

  3. Admission-policy ablation: the same traffic under FIFO vs.
     XShare-affinity admission (batch composition by gate-histogram
     overlap), comparing activated experts per layer-step — the paper's
     correlation-aware selection lifted to the scheduling layer.

Chaos mode (``--chaos``): the same traffic served under seeded
fault-injection campaigns (serving/faults.py) with the full robustness
layer armed — deadlines, bounded queue, watchdog + retry, graceful
XShare degradation, invariant checks every loop. Reports survival rate,
shed breakdown by structured reason, p99 latency of survivors, and the
chaos/fault-free OTPS ratio; persists to BENCH_robustness.json at the
repo root (CI uploads it as an artifact and sanity-checks it with
benchmarks/check_bench_schema.py).

Chaos mode also runs the **kill-and-recover** campaign: the same
requests served through the crash-tolerant front door
(serving/frontdoor.py) with a durable journal + periodic snapshots, the
process killed mid-round (SimulatedCrash + torn journal write), and a
fresh incarnation recovered from the on-disk artifacts. Reports
recovery wall time, lost admitted requests (must be 0), replay
fidelity, and whether every greedy stream is bit-identical to the
uninterrupted run.
"""
from __future__ import annotations

import argparse
import json
import os
import tempfile
import time
from typing import Dict, List

import numpy as np

from benchmarks.common import DATASETS, trained_model
from repro.serving import (Engine, Fault, FaultInjector, FrontDoor,
                           recover, sample_campaign)

BATCH = 8
MAX_NEW = 192
PROMPT_LEN = 32
DECODE_CHUNK = 32
TRAFFIC_REQUESTS = 24
TRAFFIC_MAX_NEW = 48
TRAFFIC_SLOTS = 4
TRAFFIC_CHUNK = 16            # shorter chunks: admission every 16 tokens
TRAFFIC_RATE_HZ = 40.0        # Poisson arrival rate (offered load)

CHAOS_SEEDS = (10, 25, 7)     # mixed / 3-fault / stall-only campaigns
CHAOS_MAX_NEW = 32
BENCH_PATH = os.path.join(os.path.dirname(__file__), os.pardir,
                          "BENCH_robustness.json")


def _prompts(fam, n: int, seed: int) -> List[np.ndarray]:
    """n prompts cycling over the heterogeneous dataset family."""
    rng = np.random.default_rng(seed)
    names = list(fam)
    return [fam[names[i % len(names)]].sample(rng, 1, PROMPT_LEN)[0]
            for i in range(n)]


def _traffic_run(eng: Engine, prompts, arrivals, admission: str) -> Dict:
    sched = eng.make_scheduler(num_slots=TRAFFIC_SLOTS,
                               admission=admission,
                               decode_chunk=TRAFFIC_CHUNK)
    for p, t in zip(prompts, arrivals):
        sched.submit(p, TRAFFIC_MAX_NEW, arrival_s=t)
    t0 = time.perf_counter()
    states = sched.run()
    wall = time.perf_counter() - t0
    lat = np.array([s.latency_s for s in states])
    acts = [float(np.mean(a["activated_experts"]))
            for a in sched.step_aux]
    toks = sum(len(s.tokens) for s in states)
    return {
        "admission": admission,
        "otps": toks / wall,
        "p50_latency_s": float(np.percentile(lat, 50)),
        "p99_latency_s": float(np.percentile(lat, 99)),
        "mean_ttft_s": float(np.mean([s.ttft_s for s in states])),
        "activated_experts": float(np.mean(acts)),
        "decode_steps": sched.total_steps,
    }


def run() -> dict:
    cfg, params, fam, _ = trained_model(32, 4)
    eng = Engine(cfg, params, cache_len=PROMPT_LEN + MAX_NEW + 8,
                 decode_chunk=DECODE_CHUNK)
    rng = np.random.default_rng(0)
    batch = np.stack(_prompts(fam, BATCH, seed=1))

    # -- 1. fused continuous vs. seed per-token host loop, all at t=0 ------
    eng.generate(batch, 8, lockstep=True)          # compile both paths
    eng.generate(batch, 8)
    lock_otps, cont_otps, exact = [], [], True
    for _ in range(4):               # interleaved: noise hits both sides
        toks_l, st_l = eng.generate(batch, MAX_NEW, lockstep=True)
        toks_c, st_c = eng.generate(batch, MAX_NEW)
        exact &= bool(np.array_equal(toks_l, toks_c))
        lock_otps.append(st_l.otps)
        cont_otps.append(st_c.otps)
    lockstep_best = max(lock_otps)
    fused_best = max(cont_otps)
    speedup = fused_best / lockstep_best
    rows = [{
        "config": f"lockstep bs{BATCH}", "otps": lockstep_best,
        "wall_us_per_step": 1e6 / lockstep_best * BATCH,
    }, {
        "config": f"fused bs{BATCH} chunk{DECODE_CHUNK}",
        "otps": fused_best,
        "wall_us_per_step": 1e6 / fused_best * BATCH,
        "token_exact_vs_lockstep": exact,
    }]

    # -- 2/3. Poisson traffic, FIFO vs. affinity admission -----------------
    # each policy runs twice and the SECOND run is reported: staggered
    # admission hits jit shapes (partial-group prefills, insert) the
    # bulk path never compiles, and they must not be charged to
    # whichever policy happens to run first
    prompts = _prompts(fam, TRAFFIC_REQUESTS, seed=2)
    arrivals = np.cumsum(
        rng.exponential(1.0 / TRAFFIC_RATE_HZ, TRAFFIC_REQUESTS))
    fifo = [_traffic_run(eng, prompts, arrivals, "fcfs")
            for _ in range(2)][-1]
    aff = [_traffic_run(eng, prompts, arrivals, "affinity")
           for _ in range(2)][-1]
    rows += [fifo, aff]

    act_delta = fifo["activated_experts"] - aff["activated_experts"]
    return {
        "rows": rows,
        "fused_speedup_bs8": speedup,
        "token_exact": exact,
        "affinity_activated_delta": act_delta,
        "affinity_activated_rel": act_delta
        / max(fifo["activated_experts"], 1e-9),
    }


# ---------------------------------------------------------- chaos mode ----

def _chaos_serve(eng: Engine, prompts, arrivals, injector) -> Dict:
    """One serve under the full robustness layer; asserts zero slot
    leaks and clean invariants after the drain."""
    n = len(prompts)
    sched = eng.make_scheduler(
        num_slots=TRAFFIC_SLOTS, admission="affinity",
        decode_chunk=TRAFFIC_CHUNK, faults=injector, invariants=True,
        watchdog_s=0.25, max_retries=2, retry_backoff_s=0.01,
        max_queue=n, overload="shed", degrade=True)
    for i, (p, t) in enumerate(zip(prompts, arrivals)):
        kw = dict(ttft_deadline_s=30.0, deadline_s=60.0) \
            if i % 4 == 3 else {}   # every 4th request carries deadlines
        sched.submit(p, CHAOS_MAX_NEW, arrival_s=t, **kw)
    t0 = time.perf_counter()
    states = sched.run(max_wall_s=300.0)
    wall = time.perf_counter() - t0
    assert all(s is None for s in sched._slots), "slot leak after drain"
    sched.check_invariants()
    done = [s for s in states if s.status == "done"]
    toks = sum(len(s.tokens) for s in states)
    return {
        "otps": toks / wall,
        "survival_rate": len(done) / len(states),
        "reasons": sched.reason_counts(),
        "p99_latency_s": float(np.percentile(
            [s.latency_s for s in done], 99)) if done else float("nan"),
        "stall_events": sched.stall_events,
        "retries": sched.retries,
        "degrade_peak": max((lvl for _, lvl in sched.degrade_events),
                            default=0),
    }


def _kill_recover_run(eng: Engine, prompts, *, free: np.ndarray,
                      crash_round: int) -> Dict:
    """Kill-and-recover through the front door: serve with journal +
    snapshots, die mid-round with a torn journal write, recover a fresh
    incarnation from the artifacts, and audit the contract — zero lost
    admitted requests, replay fidelity, and greedy streams bit-identical
    to the uninterrupted run (``free``)."""
    n = len(prompts)
    with tempfile.TemporaryDirectory(prefix="xshare-kill-") as tmp:
        jp = os.path.join(tmp, "wal.journal")
        sp = os.path.join(tmp, "snap")
        inj = FaultInjector([Fault("crash_mid_round", step=crash_round),
                             Fault("journal_torn_write", nbytes=9)])
        # fsync_every=1: every token record is durable, so the recovery
        # actually has a prefix to verify (replay_fidelity is measured
        # over real tokens, not trivially 1.0 on an empty set)
        door = FrontDoor(eng, num_slots=TRAFFIC_SLOTS, journal_path=jp,
                         snapshot_path=sp, snapshot_every_rounds=2,
                         fsync_every=1, decode_chunk=TRAFFIC_CHUNK,
                         faults=inj).start()
        for p in prompts:
            door.submit(p, CHAOS_MAX_NEW)
        door.drain(timeout=300.0)
        assert door.crashed is not None, \
            f"crash fault never fired (crash_round={crash_round})"
        durable_tokens = sum(len(s.tokens) for s in door.streams.values())

        t0 = time.perf_counter()
        door2, report = recover(eng, journal_path=jp, snapshot_path=sp,
                                num_slots=TRAFFIC_SLOTS,
                                decode_chunk=TRAFFIC_CHUNK)
        states = door2.drain(timeout=300.0)
        recovery_wall = time.perf_counter() - t0

        lost = sum(1 for s in states if s.finish_reason is None)
        bit_identical = all(
            np.array_equal(np.asarray([int(t) for t in s.tokens]),
                           free[s.rid]) for s in states)
        stats = door2.replay_stats()
    return {
        "requests": n,
        "snapshots_written": door.snapshots_written,
        "crash_round": crash_round,
        "durable_tokens_at_crash": durable_tokens,
        "torn_tail": report.torn_tail,
        "corrupt_gaps": report.corrupt_gaps,
        "snapshot_used": report.snapshot_used,
        "journal_records": report.journal_records,
        "resumed": report.resumed,
        "terminal": report.terminal,
        "lost_requests": lost,
        "recovery_wall_s": recovery_wall,
        "replayed_tokens": int(stats["replayed_tokens"]),
        "replay_fidelity": stats["fidelity"],
        "bit_identical": bit_identical,
    }


def run_chaos(quick: bool = False) -> dict:
    """Fault-injection campaigns over Poisson traffic; persists
    survival / shed / p99 / OTPS-ratio stats to BENCH_robustness.json."""
    cfg, params, fam, _ = trained_model(32, 4,
                                        steps=60 if quick else 150)
    n_req = 8 if quick else TRAFFIC_REQUESTS
    seeds = CHAOS_SEEDS[:1] if quick else CHAOS_SEEDS
    eng = Engine(cfg, params, cache_len=PROMPT_LEN + CHAOS_MAX_NEW + 8,
                 decode_chunk=TRAFFIC_CHUNK)
    rng = np.random.default_rng(3)
    prompts = _prompts(fam, n_req, seed=4)
    arrivals = np.cumsum(rng.exponential(1.0 / TRAFFIC_RATE_HZ, n_req))

    # the whole sequence runs twice and the SECOND pass is reported:
    # prefill-group shapes depend on arrival timing, so whichever serve
    # runs first absorbs jit compiles (including the degradation-level
    # fused fns, cached engine-wide) that must not bias the ratio
    horizon = n_req * CHAOS_MAX_NEW // TRAFFIC_SLOTS
    for _ in range(2):
        ref = _chaos_serve(eng, prompts, arrivals, None)
        campaigns = []
        for seed in seeds:
            inj = sample_campaign(seed, num_requests=n_req,
                                  num_slots=TRAFFIC_SLOTS,
                                  horizon_steps=horizon, delay_s=0.05)
            row = _chaos_serve(eng, prompts, arrivals, inj)
            row["seed"] = seed
            row["faults"] = [f.kind for f in inj.faults]
            campaigns.append(row)
    breakdown: Dict[str, int] = {}
    for c in campaigns:
        for k, v in c["reasons"].items():
            breakdown[k] = breakdown.get(k, 0) + v

    # -- kill-and-recover: crash the front door, rebuild from disk ---------
    free, _ = eng.generate(np.stack(prompts), CHAOS_MAX_NEW)
    kill = _kill_recover_run(eng, prompts, free=free, crash_round=3)
    assert kill["lost_requests"] == 0, \
        f"kill-and-recover lost {kill['lost_requests']} admitted requests"
    assert kill["bit_identical"], \
        "recovered greedy streams diverged from the uninterrupted run"

    out = {
        "fault_free": ref,
        "campaigns": campaigns,
        "survival_rate": float(np.mean(
            [c["survival_rate"] for c in campaigns])),
        "shed_breakdown": breakdown,
        "p99_latency_s": float(np.nanmax(
            [c["p99_latency_s"] for c in campaigns])),
        "chaos_otps_ratio": float(np.mean(
            [c["otps"] for c in campaigns]) / max(ref["otps"], 1e-9)),
        "kill_recover": kill,
    }
    with open(BENCH_PATH, "w") as fh:
        json.dump({"robustness": out}, fh, indent=1, default=float)
    return out


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--chaos", action="store_true",
                    help="fault-injection campaign; writes "
                         "BENCH_robustness.json")
    ap.add_argument("--quick", action="store_true",
                    help="CI smoke: 1 campaign seed, 8 requests")
    args = ap.parse_args()
    if args.chaos:
        out = run_chaos(quick=args.quick)
        for c in out["campaigns"]:
            print(c)
        print({k: v for k, v in out.items() if k != "campaigns"})
    else:
        out = run()
        for r in out["rows"]:
            print(r)
        print({k: v for k, v in out.items() if k != "rows"})
