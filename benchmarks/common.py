"""Shared benchmark infrastructure.

No AIME/GPQA offline, so the reproduction target is the paper's
*structure*: trained-router models on synthetic heterogeneous datasets,
teacher-forced decode-time cross-entropy as the accuracy proxy, and a
memory-bound OTPS model (decode step time ~ bytes of activated expert
weights, the paper's own premise) alongside CPU wall times.
"""
from __future__ import annotations

import functools
import time
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import (ArchConfig, AttnConfig, MoEConfig,
                                XSharePolicy)
from repro.data import SyntheticLM, make_dataset_family
from repro.kernels.ops import moe_step_bytes
from repro.launch.train import make_train_step
from repro.models import decode_step, init_params, prefill
from repro.models.moe import OFF
from repro.optim import adamw_init, cosine_schedule

DATASETS = ("gpqa", "aime2025", "mmlu-pro", "aa-lcr")


def bench_cfg(num_experts: int, top_k: int, *, d_model: int = 64,
              vocab: int = 256, layers: int = 2,
              d_ff_expert: int = 64, shared: int = 0) -> ArchConfig:
    return ArchConfig(
        name=f"bench-moe-{num_experts}e{top_k}k", family="moe",
        num_layers=layers, d_model=d_model, d_ff=0, vocab_size=vocab,
        attn=AttnConfig(num_heads=4, num_kv_heads=2, head_dim=16),
        moe=MoEConfig(num_experts=num_experts, top_k=top_k,
                      d_ff_expert=d_ff_expert, num_shared_experts=shared,
                      d_ff_shared=d_ff_expert if shared else 0),
    )


def draft_cfg(*, d_model: int = 32, layers: int = 1, d_ff: int = 128,
              vocab: int = 256) -> ArchConfig:
    """A dense draft model an order of magnitude cheaper per step than
    the bench MoE target — the shape speculative decoding needs for a
    real throughput win (draft bytes << target bytes)."""
    return ArchConfig(
        name=f"bench-draft-d{d_model}", family="dense",
        num_layers=layers, d_model=d_model, d_ff=d_ff, vocab_size=vocab,
        attn=AttnConfig(num_heads=2, num_kv_heads=1, head_dim=16),
    )


@functools.lru_cache(maxsize=2)
def trained_draft(steps: int = 300, seed: int = 1):
    """Train the dense draft on the same synthetic dataset family the
    bench MoE target trains on, so target/draft greedy agreement (the
    speculation acceptance rate) reflects shared data, not shared
    weights."""
    cfg = draft_cfg()
    params = init_params(cfg, jax.random.PRNGKey(seed))
    opt = adamw_init(params)
    step = jax.jit(make_train_step(
        cfg, lr=cosine_schedule(3e-3, 10, steps), remat=False))
    fam = make_dataset_family(cfg.vocab_size, DATASETS)
    rng = np.random.default_rng(seed)
    names = list(fam)
    for i in range(steps):
        lm = fam[names[i % len(names)]]
        toks = jnp.asarray(lm.sample(rng, 8, 64))
        params, opt, _ = step(params, opt, toks)
    return cfg, params


def param_bytes(params) -> int:
    """Total parameter bytes — the per-step HBM traffic of a dense
    model in the memory-bound decode regime (weights read once/step)."""
    return int(sum(np.asarray(p).nbytes
                   for p in jax.tree_util.tree_leaves(params)))


@functools.lru_cache(maxsize=4)
def trained_model(num_experts: int, top_k: int, steps: int = 150,
                  seed: int = 0):
    """Train a tiny MoE LM on the mixed synthetic dataset family."""
    cfg = bench_cfg(num_experts, top_k)
    params = init_params(cfg, jax.random.PRNGKey(seed))
    opt = adamw_init(params)
    step = jax.jit(make_train_step(
        cfg, lr=cosine_schedule(3e-3, 10, steps), remat=False,
        capacity_factor=4.0))
    fam = make_dataset_family(cfg.vocab_size, DATASETS)
    rng = np.random.default_rng(seed)
    names = list(fam)
    losses = []
    for i in range(steps):
        lm = fam[names[i % len(names)]]
        toks = jnp.asarray(lm.sample(rng, 8, 64))
        params, opt, m = step(params, opt, toks)
        losses.append(float(m["loss"]))
    return cfg, params, fam, losses


def teacher_forced_decode_ce(cfg: ArchConfig, params, tokens: np.ndarray,
                             policy: XSharePolicy, *,
                             prefill_len: int = 8,
                             spec_shape: Optional[Tuple[int, int]] = None
                             ) -> Dict:
    """Decode-phase accuracy proxy + activation statistics.

    Teacher-forced: prefill the prompt, then step through positions one
    token at a time with the XShare policy active (exactly the paper's
    decode setting), accumulating next-token CE and per-layer activated
    expert counts. tokens: (B, S) np.int32.

    If spec_shape=(b, t) is given, steps feed t tokens per request at
    once (speculative verify batch shape) so mode="spec" sees the
    hierarchical structure.
    """
    B, S = tokens.shape
    toks = jnp.asarray(tokens)
    t_step = 1 if spec_shape is None else spec_shape[1]

    pre = jax.jit(lambda p, t: prefill(cfg, p, t, cache_len=S + 8,
                                       capacity_factor=99.0))
    dec = jax.jit(lambda p, t, c: decode_step(
        cfg, p, t, c, policy=policy, spec_shape=spec_shape,
        capacity_factor=99.0))

    logits0, cache, _ = pre(params, toks[:, :prefill_len])
    nll, cnt = 0.0, 0
    acts: List[float] = []
    sel: List[float] = []
    loads: List[float] = []
    tok_loads: List[float] = []
    gmass: List[float] = []
    wall = 0.0
    logits0 = jnp.asarray(logits0, jnp.float32)
    logp = jax.nn.log_softmax(logits0)
    nll -= float(jnp.take_along_axis(
        logp, toks[:, prefill_len][:, None], axis=-1).sum())
    cnt += B
    pos = prefill_len
    while pos + t_step <= S - 1:
        t_in = toks[:, pos:pos + t_step]
        t0 = time.perf_counter()
        lg, cache, aux = dec(params, t_in, cache)
        lg.block_until_ready()
        wall += time.perf_counter() - t0
        lgf = jax.nn.log_softmax(jnp.asarray(lg, jnp.float32))
        tgt = toks[:, pos + 1:pos + t_step + 1]
        nll -= float(jnp.take_along_axis(lgf, tgt[..., None],
                                         axis=-1).sum())
        cnt += B * t_step
        if aux:
            acts.append(float(np.mean(np.asarray(
                aux["activated_experts"]))))
            sel.append(float(np.mean(np.asarray(aux["selected_set"]))))
            loads.append(float(np.max(np.asarray(
                aux["max_group_load"]))))
            tok_loads.append(float(np.max(np.asarray(
                aux["max_group_tokens"]))))
            gmass.append(float(np.mean(np.asarray(aux["gate_mass"]))))
        pos += t_step
    steps = max(1, len(acts))
    return {
        "ce": nll / max(cnt, 1),
        "activated": float(np.mean(acts)) if acts else float("nan"),
        "selected": float(np.mean(sel)) if sel else float("nan"),
        "max_load": float(np.mean(loads)) if loads else float("nan"),
        # real tokens landing on the busiest expert shard per step
        # (segment sizes under sorted dispatch), not capacity padding
        "max_shard_tokens": float(np.mean(tok_loads)) if tok_loads
        else float("nan"),
        "gate_mass": float(np.mean(gmass)) if gmass else float("nan"),
        "wall_us_per_step": 1e6 * wall / steps,
    }


def otps_model(cfg: ArchConfig, activated: float, tokens: int) -> float:
    """Relative decode throughput in the memory-bound regime: step time
    ~ HBM bytes, dominated by activated expert weights (the paper's
    premise, Sec 1). Returns tokens/sec in model units (1/bytes)."""
    per_layer = moe_step_bytes(activated, cfg.d_model,
                               cfg.moe.d_ff_expert, tokens=tokens,
                               top_k=cfg.moe.top_k)
    return 1e9 / (per_layer * cfg.num_layers)


def eval_tokens(fam, names, *, batch_per: int, seq: int,
                seed: int = 123) -> np.ndarray:
    rng = np.random.default_rng(seed)
    return np.concatenate(
        [fam[n].sample(rng, batch_per, seq) for n in names], axis=0)
