"""Sanity-check BENCH_*.json artifacts before CI uploads them.

Benchmarks persist machine-read metrics (BENCH_dispatch.json,
BENCH_spec.json, BENCH_ep.json, BENCH_robustness.json) that downstream
tooling and the README tables consume. A refactor that silently renames a key, emits NaN, or drops a
section would still "pass" the benchmark run — this checker fails the
CI job instead.

Two layers:

  * structural — every file is a JSON object whose leaves are finite
    numbers / strings / bools / null (no NaN/inf: ``json.dump`` writes
    them as non-standard tokens many parsers reject);
  * per-file contracts (SPECS) — required key paths with value
    predicates, e.g. the robustness artifact must carry a
    ``kill_recover`` section with ``lost_requests == 0`` and
    ``bit_identical == true``.

Usage: ``python -m benchmarks.check_bench_schema [files...]``
(defaults to every BENCH_*.json at the repo root; a file listed in
SPECS but absent on disk is skipped — each CI job produces only its
own artifact).
"""
from __future__ import annotations

import glob
import json
import math
import os
import sys
from typing import Any, Callable, Dict, List, Tuple

ROOT = os.path.join(os.path.dirname(__file__), os.pardir)


def _num(lo: float = None, hi: float = None) -> Callable[[Any], bool]:
    def check(v):
        if not isinstance(v, (int, float)) or isinstance(v, bool):
            return False
        if math.isnan(v) or math.isinf(v):
            return False
        return (lo is None or v >= lo) and (hi is None or v <= hi)
    return check


def _is(val) -> Callable[[Any], bool]:
    return lambda v: v == val


def _count_map(v) -> bool:
    return isinstance(v, dict) and all(
        isinstance(k, str) and isinstance(n, int) and n >= 0
        for k, n in v.items())


# required key paths ("a.b.c") -> predicate, per artifact
SPECS: Dict[str, Dict[str, Callable[[Any], bool]]] = {
    "BENCH_dispatch.json": {
        "dispatch.speedup": _num(lo=0.0),
        "dispatch.sorted_wall_ms": _num(lo=0.0),
        "dispatch.einsum_wall_ms": _num(lo=0.0),
        "dispatch.sorted_vs_einsum_err": _num(lo=0.0),
    },
    "BENCH_spec.json": {
        # the speculative-decoding acceptance criteria, machine-checked:
        # scheduler-spec must beat plain decoding in the memory-bound
        # OTPS model, stay lossless (token-exact, incl. mixed traffic),
        # and hierarchical selection must activate fewer experts than
        # naive per-request top-k
        "spec.speedup": _num(lo=1.0),
        "spec.speedup_wall": _num(lo=0.0),
        "spec.acceptance_rate": _num(0.0, 1.0),
        "spec.drafted": _num(lo=1),
        "spec.tokens_per_round": _num(lo=0.0),
        "spec.token_exact_vs_plain": _is(True),
        "spec.token_exact_vs_lockstep": _is(True),
        "spec.token_exact_mixed": _is(True),
        "spec.activated_hier": _num(lo=0.0),
        "spec.activated_naive": _num(lo=0.0),
        "spec.activated_ratio": _num(0.0, 1.0),
        "spec.spec_budget_exhausted": _num(lo=0),
    },
    "BENCH_ep.json": {
        # the expert-parallel execution acceptance criteria: the
        # measured shard_map path must stay token-exact against the
        # single-device sorted reference, and Algorithm 6 + hot-expert
        # replication must cut measured peak-shard executed rows >= 2x
        # vs baseline routing at batch 16 (mean over decode steps)
        "ep.batch": _is(16),
        "ep.steps": _num(lo=1),
        "ep.exact_vs_single_device": _is(True),
        "ep.peak_rows_ratio": _num(lo=2.0),
        "ep.peak_rows_ratio_alg6": _num(lo=0.0),
        "ep.a2a_bytes_baseline": _num(lo=0.0),
        "ep.a2a_bytes_xshare": _num(lo=0.0),
        "ep.replication_factor": _num(lo=1.0),
        "ep.rebalances": _num(lo=0),
        "ep.rebalances_skipped": _num(lo=0),
        # speculative verify-batch shape B x (1 + L_s) must execute
        # exactly too, and not regress past baseline peak rows
        "ep.spec_peak_rows_ratio": _num(lo=1.0),
        "ep.spec_exact_vs_single_device": _is(True),
    },
    "BENCH_robustness.json": {
        "robustness.survival_rate": _num(0.0, 1.0),
        "robustness.shed_breakdown": _count_map,
        "robustness.p99_latency_s": _num(lo=0.0),
        "robustness.chaos_otps_ratio": _num(lo=0.0),
        "robustness.fault_free.otps": _num(lo=0.0),
        "robustness.campaigns": lambda v: isinstance(v, list) and v,
        # the crash-tolerance acceptance criteria, machine-checked
        "robustness.kill_recover.lost_requests": _is(0),
        "robustness.kill_recover.corrupt_gaps": _is(0),
        "robustness.kill_recover.bit_identical": _is(True),
        "robustness.kill_recover.replay_fidelity": _num(0.0, 1.0),
        "robustness.kill_recover.recovery_wall_s": _num(lo=0.0),
        "robustness.kill_recover.snapshots_written": _num(lo=0),
        "robustness.kill_recover.resumed": _num(lo=0),
    },
}


def _walk(obj, path: str, errors: List[str]) -> None:
    if isinstance(obj, dict):
        for k, v in obj.items():
            if not isinstance(k, str):
                errors.append(f"{path}: non-string key {k!r}")
            _walk(v, f"{path}.{k}" if path else str(k), errors)
    elif isinstance(obj, list):
        for i, v in enumerate(obj):
            _walk(v, f"{path}[{i}]", errors)
    elif isinstance(obj, float) and (math.isnan(obj) or math.isinf(obj)):
        errors.append(f"{path}: non-finite number {obj!r}")
    elif obj is not None and not isinstance(obj, (str, int, float, bool)):
        errors.append(f"{path}: non-JSON leaf {type(obj).__name__}")


def _lookup(obj, dotted: str) -> Tuple[bool, Any]:
    cur = obj
    for part in dotted.split("."):
        if not isinstance(cur, dict) or part not in cur:
            return False, None
        cur = cur[part]
    return True, cur


def check_file(path: str) -> List[str]:
    """All schema violations for one artifact (empty = clean)."""
    errors: List[str] = []
    try:
        with open(path) as f:
            data = json.load(f)
    except OSError as e:
        return [f"{path}: unreadable ({e})"]
    except ValueError as e:
        return [f"{path}: invalid JSON ({e})"]
    if not isinstance(data, dict):
        return [f"{path}: top level must be an object"]
    _walk(data, "", errors)
    for dotted, pred in SPECS.get(os.path.basename(path), {}).items():
        found, val = _lookup(data, dotted)
        if not found:
            errors.append(f"{path}: missing required key {dotted}")
        elif not pred(val):
            errors.append(f"{path}: {dotted} = {val!r} fails its contract")
    return [f"{path}: {e}" if not e.startswith(path) else e
            for e in errors]


def main(argv: List[str]) -> int:
    files = argv or sorted(glob.glob(os.path.join(ROOT, "BENCH_*.json")))
    if not files:
        print("check_bench_schema: no BENCH_*.json artifacts found",
              file=sys.stderr)
        return 1
    failures: List[str] = []
    for path in files:
        errs = check_file(path)
        failures.extend(errs)
        print(f"{os.path.basename(path)}: "
              f"{'OK' if not errs else f'{len(errs)} violation(s)'}")
    for e in failures:
        print(f"  {e}", file=sys.stderr)
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
