"""Expert-parallel execution: the shard_map EP path must be token-exact
against the single-device sorted pipeline (which is itself checked
against the einsum reference and the dense oracle), across top_k,
ragged skewed loads, masked continuous-batching tokens, replicated hot
experts, and XShare-restricted routing — plus unit coverage of the
histogram-driven placement planner (LPT assignment, deterministic
tie-breaks, replication, rebalance hysteresis).

conftest.py forces an 8-device emulated CPU platform, so the ragged
all-to-all here exchanges rows between real XLA devices in every
tier-1 run.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import ep as EP
from repro.configs.base import MoEConfig, XSharePolicy
from repro.models import dispatch as DSP
from repro.models.moe import expert_ffn, init_moe, route
from repro.sharding import make_ep_mesh

S = 8          # EP shards (== emulated device count)
D = 16         # d_model
E = 16         # experts
F = 32         # d_ff


@pytest.fixture(scope="module")
def mesh():
    if len(jax.devices()) < S:
        pytest.skip(f"needs {S} devices (conftest XLA_FLAGS forcing)")
    return make_ep_mesh(S)


@pytest.fixture(scope="module")
def weights():
    moe = MoEConfig(num_experts=E, top_k=2, d_ff_expert=F)
    return moe, init_moe(jax.random.PRNGKey(0), moe, D, jnp.float32)


@pytest.fixture(scope="module")
def exec_contig(mesh):
    # one executor for the whole module: compiled shard_map variants
    # are cached per shape, so tests sharing (T, k) share compiles
    return EP.EPExecutor(mesh, EP.contiguous_placement(E, S))


def routing(T, k, seed=0):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.standard_normal((T, D)), jnp.float32)
    # distinct experts per token (real top-k semantics — the einsum
    # reference's one-hot dispatch assumes no within-row duplicates)
    idx = np.stack([rng.permutation(E)[:k] for _ in range(T)])
    w = jnp.asarray(rng.random((T, k)) + 0.1, jnp.float32)
    return x, jnp.asarray(idx, jnp.int32), w


def sorted_ref(p, x, idx, w):
    return DSP.sorted_expert_ffn(x, p["w1"], p["w3"], p["w2"], idx, w)


# ------------------------------------------------- three-way parity -------

@pytest.mark.parametrize("k", [1, 2, 8])
def test_ep_sorted_einsum_three_way(mesh, weights, exec_contig, k):
    """shard_map EP == single-device sorted (exact) == einsum reference
    (float tolerance) for top_k in {1, 2, 8}."""
    moe, p = weights
    T = 40
    x, idx, w = routing(T, k, seed=k)
    y_sorted = sorted_ref(p, x, idx, w)
    y_ep, stats = exec_contig(x, p["w1"], p["w3"], p["w2"], idx, w)
    assert np.array_equal(np.asarray(y_ep), np.asarray(y_sorted))
    assert stats.count_matrix.sum() == T * k
    y_einsum = expert_ffn(p, x, idx, w, moe, capacity=T, dispatch="einsum",
                          group_size=10 ** 9)
    np.testing.assert_allclose(np.asarray(y_ep), np.asarray(y_einsum),
                               atol=1e-4)


def test_ep_ragged_skew_and_token_mask(mesh, weights, exec_contig):
    """Heavily skewed expert loads + masked continuous-batching slots
    (idx == -1, w == 0): masked tokens ship no rows and the output
    stays exact. T not divisible by S exercises the pad path."""
    moe, p = weights
    T, k = 43, 2
    x, idx, w = routing(T, k, seed=7)
    idx = idx.at[: T // 2].set(3)             # most pairs on one expert
    idx = idx.at[5:9].set(-1)                 # inactive slots
    w = w.at[5:9].set(0.0)
    w = w.at[12, 1].set(0.0)                  # single dead pair
    y_ep, stats = exec_contig(x, p["w1"], p["w3"], p["w2"], idx, w)
    assert np.array_equal(np.asarray(y_ep),
                          np.asarray(sorted_ref(p, x, idx, w)))
    live = int(((np.asarray(idx).reshape(-1) >= 0)
                & (np.asarray(w).reshape(-1) != 0)).sum())
    assert stats.count_matrix.sum() == live
    # expert 3 lives on one shard under contiguous placement: that
    # shard's computed rows must dominate
    assert stats.peak_rows >= live // 2


def test_ep_replicated_hot_expert(mesh, weights):
    """Replicating the hottest expert splits its rows across replicas
    (token-id modulus) and cuts the measured peak, exactly."""
    moe, p = weights
    T, k = 40, 2
    x, idx, w = routing(T, k, seed=11)
    idx = jnp.zeros_like(idx)                 # every pair -> expert 0
    load = np.zeros(E)
    load[0] = T * k
    ex_plain = EP.EPExecutor(mesh, EP.plan_placement(load, S))
    ex_rep = EP.EPExecutor(
        mesh, EP.plan_placement(load, S, replicate_hot=1, max_replicas=4))
    y_plain, st_plain = ex_plain(x, p["w1"], p["w3"], p["w2"], idx, w)
    y_rep, st_rep = ex_rep(x, p["w1"], p["w3"], p["w2"], idx, w)
    ref = sorted_ref(p, x, idx, w)
    assert np.array_equal(np.asarray(y_plain), np.asarray(ref))
    assert np.array_equal(np.asarray(y_rep), np.asarray(ref))
    assert st_plain.peak_rows == T * k        # one shard eats everything
    assert st_rep.peak_rows <= -(-T * k // 4) + S   # ~1/4 per replica
    assert st_rep.count_matrix.sum() == T * k


def test_ep_xshare_restricted_routing(mesh, weights, exec_contig):
    """Routing through the real router under an XShare ep-mode policy
    (Algorithm 6 per-group budgets) stays exact end to end."""
    moe, p = weights
    T = 40
    x, _, _ = routing(T, moe.top_k, seed=3)
    policy = XSharePolicy(mode="ep", k0=1, m_g=1, num_groups=8)
    idx, w, _, _ = route(p, x, moe, policy)
    y_ep, _ = exec_contig(x, p["w1"], p["w3"], p["w2"], idx, w)
    assert np.array_equal(np.asarray(y_ep),
                          np.asarray(sorted_ref(p, x, idx, w)))


def test_ep_auto_max_rows(mesh, weights, exec_contig):
    """max_rows="auto" (counts exchanged first, payload padded to the
    pow2-bucketed per-round max) shrinks the exchange buffer and still
    matches the worst-case-padded result bit for bit."""
    moe, p = weights
    T, k = 40, 2
    x, idx, w = routing(T, k, seed=2)
    y_full, st_full = exec_contig(x, p["w1"], p["w3"], p["w2"], idx, w)
    y_auto, st_auto = exec_contig(x, p["w1"], p["w3"], p["w2"], idx, w,
                                  max_rows="auto")
    assert st_auto.max_rows < st_full.max_rows
    assert np.array_equal(np.asarray(y_auto), np.asarray(y_full))


def test_ep_dispatch_mode(mesh, weights, exec_contig):
    """expert_ffn(dispatch="ep") routes through the bound executor and
    degrades to the bit-identical sorted path when none is bound."""
    moe, p = weights
    T, k = 40, 2
    x, idx, w = routing(T, k, seed=5)
    y_sorted = expert_ffn(p, x, idx, w, moe, dispatch="sorted")
    y_unbound = expert_ffn(p, x, idx, w, moe, dispatch="ep")
    assert np.array_equal(np.asarray(y_unbound), np.asarray(y_sorted))
    with EP.ep_context(exec_contig):
        y_bound = expert_ffn(p, x, idx, w, moe, dispatch="ep")
    assert EP.current_executor() is None
    assert np.array_equal(np.asarray(y_bound), np.asarray(y_sorted))


def test_exchange_counts_matches_stats(mesh, weights, exec_contig):
    moe, p = weights
    x, idx, w = routing(40, 2, seed=9)
    cm = EP.exchange_counts(idx, w, exec_contig.placement, mesh=mesh)
    _, stats = exec_contig(x, p["w1"], p["w3"], p["w2"], idx, w)
    assert np.array_equal(cm, stats.count_matrix)


# ------------------------------------------------- placement planner ------

def skewed_load(E_, seed=0, alpha=1.2):
    rng = np.random.default_rng(seed)
    return np.sort(rng.pareto(alpha, E_) + 0.1)[::-1].copy()


def test_lpt_no_worse_than_contiguous():
    for seed in range(5):
        load = skewed_load(E, seed)
        lpt = EP.plan_placement(load, S)
        contig = EP.contiguous_placement(E, S)
        assert EP.placement_peak(lpt, load) <= \
            EP.placement_peak(contig, load)


def test_placement_deterministic_ties():
    load = np.ones(E)                         # every assignment tied
    a = EP.plan_placement(load, S, replicate_hot=2)
    b = EP.plan_placement(load, S, replicate_hot=2)
    assert np.array_equal(a.hosts, b.hosts)
    assert np.array_equal(a.local_eids, b.local_eids)
    assert np.array_equal(a.local_slot, b.local_slot)


def test_replication_reduces_predicted_peak():
    load = np.ones(E)
    load[0] = 100.0
    base = EP.plan_placement(load, S)
    rep = EP.plan_placement(load, S, replicate_hot=1, max_replicas=4)
    assert EP.placement_peak(rep, load) < EP.placement_peak(base, load)
    assert rep.nhosts[0] == 4
    assert rep.replication_factor > 1.0


def test_placement_tables_roundtrip():
    load = skewed_load(E, 3)
    pl = EP.plan_placement(load, S, replicate_hot=3, max_replicas=3)
    for e in range(E):
        for r in range(pl.nhosts[e]):
            s = pl.hosts[e, r]
            slot = pl.local_slot[s, e]
            assert slot >= 0
            assert pl.local_eids[s, slot] == e


def test_rebalance_hysteresis():
    load = np.ones(E)
    load[0] = 100.0
    # contiguous start vs a hot expert: big predicted win -> adopted
    prev = EP.contiguous_placement(E, S)
    new, changed = EP.rebalance(prev, load, replicate_hot=1,
                                max_replicas=4, hysteresis=0.1)
    assert changed and new.version == prev.version + 1
    # same load again: no further win -> hysteresis keeps the placement
    again, changed2 = EP.rebalance(new, load, replicate_hot=1,
                                   max_replicas=4, hysteresis=0.1)
    assert not changed2 and again is new


def test_executor_update_placement(mesh):
    load = np.ones(E)
    load[0] = 100.0
    ex = EP.EPExecutor(mesh, EP.contiguous_placement(E, S),
                       replicate_hot=1, max_replicas=4)
    assert ex.update_placement(load)
    assert ex.rebalances == 1
    assert not ex.update_placement(load)
    assert ex.rebalances_skipped == 1


def test_executor_from_config(mesh, weights):
    """EPConfig -> executor wiring: knobs land, priors shape the initial
    placement, and the configured path stays exact."""
    from repro.configs.base import EPConfig
    cfg = EPConfig(num_shards=S, replicate_hot=1, max_replicas=2,
                   rebalance_hysteresis=0.25)
    load = np.ones(E)
    load[3] = 50.0
    ex = EP.EPExecutor.from_config(cfg, E, mesh=mesh, load=load)
    assert ex.hysteresis == 0.25
    assert ex.placement.nhosts[3] == 2          # hottest got replicated
    _, p = weights
    x, idx, w = routing(24, 2, seed=9)
    y, _ = ex(x, p["w1"], p["w3"], p["w2"], idx, w)
    assert np.array_equal(np.asarray(y),
                          np.asarray(sorted_ref(p, x, idx, w)))
    # no mesh given: builds its own over the same 8 devices
    ex2 = EP.EPExecutor.from_config(EPConfig(num_shards=S), E)
    assert ex2.placement.num_shards == S


# ------------------------------------- group math, E % G != 0 (fix) -------

def test_group_loads_non_divisible():
    """E=6 over G=4 groups: ceil-width groups [2,2,2,0] — the old code
    collapsed to a single group and misreported shard load."""
    counts = jnp.asarray([1, 2, 3, 4, 5, 6], jnp.int32)
    loads = np.asarray(DSP.group_token_loads(counts, 4))
    assert loads.tolist() == [3, 7, 11, 0]
    from repro.core.metrics import max_group_load, per_group_load
    active = jnp.asarray([1, 0, 1, 1, 0, 1], bool)
    assert np.asarray(per_group_load(active, 4)).tolist() == [1, 2, 1, 0]
    assert int(max_group_load(active, 4)) == 2


def test_ep_select_non_divisible_groups():
    """Algorithm 6 selection with E % G != 0 keeps per-group budgets on
    the ceil-width partition (padding can never be selected)."""
    from repro.core.selection import ep_select
    rng = np.random.default_rng(0)
    gates = jnp.asarray(rng.random((12, 6)), jnp.float32)
    mask = np.asarray(ep_select(gates, 1, 4, 0, strict_cap=True))
    assert mask.shape == (6,)
    loads = np.asarray(DSP.group_token_loads(
        jnp.asarray(mask, jnp.int32), 4))
    assert (loads <= 1).all()


def test_dispatch_plan_pad_shards():
    """pad_shards keeps the sorted tile axis divisible by the shard
    count (outer-mesh layouts) and pad_shards=1 opts the EP executor's
    per-shard plans out of the ambient-mesh padding."""
    idx = jnp.asarray([[0], [1], [2]], jnp.int32)
    w = jnp.ones((3, 1), jnp.float32)
    plan = DSP.dispatch_plan(idx, w, 4, block_t=8, pad_shards=8)
    assert plan.padded_rows % (8 * 8) == 0
    plan1 = DSP.dispatch_plan(idx, w, 4, block_t=8, pad_shards=1)
    assert plan1.padded_rows < plan.padded_rows
