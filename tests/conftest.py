"""Test config. NOTE: no XLA_FLAGS device-count forcing here — smoke
tests and benches must see the real (single-device) platform; only
launch/dryrun.py forces 512 host devices, and the small-mesh integration
test does so in a subprocess."""
import jax

jax.config.update("jax_enable_x64", False)
