"""Test config.

Every tier-1 run emulates an 8-device CPU platform (the XLA host-
platform device-count flag below, set BEFORE jax imports), so the
shard_map expert-parallel path (ep/executor.py, tests/test_ep.py) runs
real per-shard collectives in-process instead of being skipped on
single-device machines. CI sets the same flag at the job level.

Single-device semantics are unaffected: tests build meshes explicitly
(``make_ep_mesh`` / ``make_mesh_compat``) and nothing auto-shards over
the extra devices — code that doesn't ask for a mesh still runs on
device 0. launch/dryrun.py and the dry-run integration test spawn
subprocesses with their own XLA_FLAGS (512 emulated hosts) and are
likewise untouched.
"""
import os

_FLAG = "--xla_force_host_platform_device_count=8"
if _FLAG not in os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = (
        os.environ.get("XLA_FLAGS", "") + " " + _FLAG).strip()

import jax  # noqa: E402  (must follow the XLA_FLAGS export)

jax.config.update("jax_enable_x64", False)
