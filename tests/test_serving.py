"""Serving engine: speculative decoding MUST equal plain greedy decoding
(the fundamental lossless-speculation invariant), ragged acceptance,
policy accounting, heterogeneous batches."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import XSharePolicy
from repro.configs.registry import ARCHS
from repro.models import init_params
from repro.serving import Engine, greedy_accept


def small(name, **kw):
    return ARCHS[name].reduced(num_layers=2, max_d_model=128,
                               max_vocab=256, **kw)


@pytest.fixture(scope="module")
def moe_setup():
    cfg = small("granite-moe-1b-a400m")
    params = init_params(cfg, jax.random.PRNGKey(1))
    prompts = np.asarray(jax.random.randint(
        jax.random.PRNGKey(2), (3, 12), 0, cfg.vocab_size))
    return cfg, params, prompts


def test_spec_equals_plain_self_draft(moe_setup):
    """Both speculative paths — the scheduler-integrated subsystem
    (default) and the retained lockstep reference — must equal plain
    greedy decoding token for token."""
    cfg, params, prompts = moe_setup
    plain, _ = Engine(cfg, params, cache_len=128).generate(prompts, 20)
    eng = Engine(cfg, params, cache_len=128, draft=(cfg, params),
                 spec_len=3)
    spec, st = eng.generate(prompts, 20)
    assert np.array_equal(plain, spec)
    assert st.acceptance_rate == 1.0        # identical draft: all accepted
    lock, lst = eng.generate(prompts, 20, lockstep=True)
    assert np.array_equal(plain, lock)
    assert lst.mean_accepted == 3.0         # full L_s every lockstep round


def test_spec_equals_plain_perturbed_draft(moe_setup):
    cfg, params, prompts = moe_setup
    pert = jax.tree_util.tree_map(
        lambda a: a + 0.02 * jax.random.normal(jax.random.PRNGKey(9),
                                               a.shape, a.dtype),
        params)
    plain, _ = Engine(cfg, params, cache_len=128).generate(prompts, 20)
    eng = Engine(cfg, params, cache_len=128, draft=(cfg, pert),
                 spec_len=3)
    spec, st = eng.generate(prompts, 20)
    assert np.array_equal(plain, spec)
    assert 0.0 <= st.acceptance_rate <= 1.0  # ragged acceptance exercised
    lock, _ = eng.generate(prompts, 20, lockstep=True)
    assert np.array_equal(plain, lock)


def test_spec_equals_plain_window_cache():
    cfg = small("h2o-danube-1.8b")
    assert cfg.attn.sliding_window
    params = init_params(cfg, jax.random.PRNGKey(3))
    prompts = np.asarray(jax.random.randint(
        jax.random.PRNGKey(4), (2, 10), 0, cfg.vocab_size))
    plain, _ = Engine(cfg, params, cache_len=128).generate(prompts, 30)
    spec, _ = Engine(cfg, params, cache_len=128, draft=(cfg, params),
                     spec_len=4).generate(prompts, 30)
    assert np.array_equal(plain, spec)


def test_spec_policy_collects_expert_stats(moe_setup):
    cfg, params, prompts = moe_setup
    pol = XSharePolicy(mode="spec", k0=1, m_l=0, m_r=2)
    eng = Engine(cfg, params, cache_len=128, policy=pol,
                 draft=(cfg, params), spec_len=3)
    toks, st = eng.generate(prompts, 16)
    assert st.layer_aux, "MoE layer stats must be recorded"
    assert st.mean_aux("selected_set") <= cfg.moe.num_experts
    assert st.mean_aux("activated_experts") <= st.mean_aux("selected_set") \
        + 1e-6


def test_greedy_accept_unit():
    V = 8
    # drafts [3, 5]; target argmax [3, 2, 7] -> accept 1 draft + bonus 2
    logits = jnp.full((1, 3, V), -10.0)
    logits = logits.at[0, 0, 3].set(10.0).at[0, 1, 2].set(10.0) \
                   .at[0, 2, 7].set(10.0)
    res = greedy_accept(logits, jnp.array([[3, 5]]))
    assert int(res.accepted[0]) == 1
    assert int(res.num_new[0]) == 2
    assert res.new_tokens[0, 0] == 3 and res.new_tokens[0, 1] == 2


def test_plain_generation_audio_codebooks():
    cfg = small("musicgen-large")
    params = init_params(cfg, jax.random.PRNGKey(5))
    prompts = np.asarray(jax.random.randint(
        jax.random.PRNGKey(6), (2, 8, cfg.num_codebooks), 0,
        cfg.vocab_size))
    toks, st = Engine(cfg, params, cache_len=64).generate(prompts, 6)
    assert toks.shape == (2, 6, cfg.num_codebooks)
    assert st.new_tokens == 2 * 6 * cfg.num_codebooks


def test_temperature_sampling_differs_from_greedy(moe_setup):
    cfg, params, prompts = moe_setup
    g, _ = Engine(cfg, params, cache_len=128).generate(prompts, 16)
    s, _ = Engine(cfg, params, cache_len=128, temperature=1.5,
                  seed=7).generate(prompts, 16)
    assert not np.array_equal(g, s)
