"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps in interpret
mode (kernel bodies execute in Python on CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.ops import flash_decode, ssd_chunk_scan, xshare_moe_ffn
from repro.kernels.ref import decode_attn_ref, moe_ffn_ref, ssd_chunk_ref


def tol(dtype):
    return 2e-2 if dtype == jnp.bfloat16 else 2e-5


# ----------------------------------------------------------- moe_ffn ------

@pytest.mark.parametrize("T,d,E,f,blockf", [
    (8, 64, 4, 128, 64), (16, 128, 8, 256, 128), (4, 32, 16, 64, 64),
    (32, 128, 6, 96, 32),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_moe_ffn_kernel_matches_ref(T, d, E, f, blockf, dtype):
    key = jax.random.PRNGKey(T + E)
    ks = jax.random.split(key, 6)
    x = jax.random.normal(ks[0], (T, d), dtype)
    w1 = (jax.random.normal(ks[1], (E, d, f)) * 0.05).astype(dtype)
    w3 = (jax.random.normal(ks[2], (E, d, f)) * 0.05).astype(dtype)
    w2 = (jax.random.normal(ks[3], (E, f, d)) * 0.05).astype(dtype)
    logits = jax.random.normal(ks[4], (T, E))
    top, idx = jax.lax.top_k(logits, 2)
    w = jax.nn.softmax(top, -1)
    combine = (jax.nn.one_hot(idx, E) * w[..., None]).sum(-2)
    n_act = max(1, E // 2)
    active = jnp.zeros(E, bool).at[
        jax.random.permutation(ks[5], E)[:n_act]].set(True)
    combine = jnp.where(active[None], combine, 0.0).astype(jnp.float32)
    ref = moe_ffn_ref(x, w1, w3, w2, combine, active)
    out = xshare_moe_ffn(x, w1, w3, w2, combine, active,
                         max_active=n_act + 1, block_f=blockf)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               atol=tol(dtype), rtol=tol(dtype))


def test_moe_ffn_all_inactive_is_zero():
    T, d, E, f = 4, 32, 4, 64
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (T, d))
    w1 = jax.random.normal(key, (E, d, f))
    w3 = jax.random.normal(key, (E, d, f))
    w2 = jax.random.normal(key, (E, f, d))
    combine = jnp.zeros((T, E))
    active = jnp.zeros(E, bool)
    out = xshare_moe_ffn(x, w1, w3, w2, combine, active, max_active=2,
                         block_f=64)
    assert float(jnp.abs(out).max()) == 0.0


# ------------------------------------------------- grouped (sorted) -------

@pytest.mark.parametrize("T,d,E,f,blockf,k", [
    (8, 64, 4, 128, 64, 2), (16, 128, 8, 256, 128, 1),
    (32, 128, 6, 96, 32, 2),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_grouped_ffn_kernel_matches_ref(T, d, E, f, blockf, k, dtype):
    """Pallas grouped_ffn through the full sorted pipeline == the dense
    masked-expert oracle."""
    from repro.models.dispatch import sorted_expert_ffn
    key = jax.random.PRNGKey(T * E + k)
    ks = jax.random.split(key, 5)
    x = jax.random.normal(ks[0], (T, d), dtype)
    w1 = (jax.random.normal(ks[1], (E, d, f)) * 0.05).astype(dtype)
    w3 = (jax.random.normal(ks[2], (E, d, f)) * 0.05).astype(dtype)
    w2 = (jax.random.normal(ks[3], (E, f, d)) * 0.05).astype(dtype)
    logits = jax.random.normal(ks[4], (T, E))
    top, idx = jax.lax.top_k(logits, k)
    w = jax.nn.softmax(top, -1)
    combine = (jax.nn.one_hot(idx, E) * w[..., None]).sum(-2)
    ref = moe_ffn_ref(x, w1, w3, w2, combine.astype(jnp.float32),
                      jnp.ones((E,), bool))
    out = sorted_expert_ffn(x, w1, w3, w2, idx, w, use_kernel=True,
                            block_f=blockf)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               atol=tol(dtype), rtol=tol(dtype))


def test_grouped_ffn_empty_experts_zero_tiles():
    """Unrouted experts own no valid tiles; all-dropped routing yields
    zero output."""
    from repro.models.dispatch import dispatch_plan, sorted_expert_ffn
    T, d, E, f = 8, 32, 4, 64
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (T, d))
    w1 = jax.random.normal(key, (E, d, f))
    w3 = jax.random.normal(key, (E, d, f))
    w2 = jax.random.normal(key, (E, f, d))
    idx = jnp.full((T, 1), -1, jnp.int32)
    w = jnp.zeros((T, 1))
    plan = dispatch_plan(idx, w, E)
    assert int(jnp.asarray(plan.tile_valid).sum()) == 0
    out = sorted_expert_ffn(x, w1, w3, w2, idx, w, use_kernel=True)
    assert float(jnp.abs(out).max()) == 0.0


# ------------------------------------------------------- decode_attn ------

@pytest.mark.parametrize("B,H,Hkv,dh,S,bs", [
    (2, 8, 2, 64, 256, 64), (3, 4, 4, 32, 100, 32),
    (1, 16, 2, 128, 1024, 256), (2, 4, 1, 64, 64, 16),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_decode_attention_matches_ref(B, H, Hkv, dh, S, bs, dtype):
    ks = jax.random.split(jax.random.PRNGKey(B * S), 4)
    q = jax.random.normal(ks[0], (B, H, dh), dtype)
    k = jax.random.normal(ks[1], (B, S, Hkv, dh), dtype)
    v = jax.random.normal(ks[2], (B, S, Hkv, dh), dtype)
    lengths = jax.random.randint(ks[3], (B,), 1, S + 1)
    out = flash_decode(q, k, v, lengths, block_s=bs)
    ref = decode_attn_ref(q, k, v, lengths)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               atol=tol(dtype), rtol=tol(dtype))


def test_decode_attention_length_masking():
    """Tokens beyond the length must not influence the output."""
    B, H, Hkv, dh, S = 1, 4, 2, 32, 64
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (B, H, dh))
    k = jax.random.normal(ks[1], (B, S, Hkv, dh))
    v = jax.random.normal(ks[2], (B, S, Hkv, dh))
    lengths = jnp.array([17])
    out1 = flash_decode(q, k, v, lengths, block_s=16)
    k2 = k.at[:, 17:].set(99.0)
    v2 = v.at[:, 17:].set(-99.0)
    out2 = flash_decode(q, k2, v2, lengths, block_s=16)
    np.testing.assert_allclose(np.asarray(out1), np.asarray(out2),
                               atol=1e-6)


# ----------------------------------------------------------- ssd_scan -----

@pytest.mark.parametrize("B,S,nh,hd,ds,chunk,bh", [
    (2, 64, 4, 32, 16, 16, 2), (1, 100, 8, 64, 32, 32, 8),
    (2, 256, 2, 64, 128, 128, 2), (1, 48, 4, 32, 64, 64, 4),
])
def test_ssd_scan_matches_sequential_ref(B, S, nh, hd, ds, chunk, bh):
    ks = jax.random.split(jax.random.PRNGKey(S), 5)
    x = jax.random.normal(ks[0], (B, S, nh, hd)) * 0.5
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, nh)))
    A = -jnp.exp(jax.random.normal(ks[2], (nh,)) * 0.3)
    Bm = jax.random.normal(ks[3], (B, S, nh, ds)) * 0.3
    Cm = jax.random.normal(ks[4], (B, S, nh, ds)) * 0.3
    y, st = ssd_chunk_scan(x, dt, A, Bm, Cm, chunk=chunk, block_h=bh)
    yr, sr = ssd_chunk_ref(x, dt, A, Bm, Cm)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr),
                               atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(np.asarray(st), np.asarray(sr),
                               atol=1e-4, rtol=1e-4)


def test_ssd_scan_bf16_inputs():
    B, S, nh, hd, ds = 1, 64, 2, 32, 16
    ks = jax.random.split(jax.random.PRNGKey(7), 5)
    x = (jax.random.normal(ks[0], (B, S, nh, hd)) * 0.5).astype(jnp.bfloat16)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, nh)))
    A = -jnp.exp(jax.random.normal(ks[2], (nh,)) * 0.3)
    Bm = (jax.random.normal(ks[3], (B, S, nh, ds)) * 0.3).astype(jnp.bfloat16)
    Cm = (jax.random.normal(ks[4], (B, S, nh, ds)) * 0.3).astype(jnp.bfloat16)
    y, st = ssd_chunk_scan(x, dt, A, Bm, Cm, chunk=32, block_h=2)
    yr, sr = ssd_chunk_ref(x, dt, A, Bm, Cm)
    np.testing.assert_allclose(np.asarray(y, np.float32),
                               np.asarray(yr, np.float32), atol=5e-2,
                               rtol=5e-2)
