"""serving/journal.py: CRC framing, fsync batching, torn-write
tolerance, snapshot atomicity, and idempotent record folding.

All host-side — no model, no jit. These lock down the durability
semantics the crash-recovery path (serving/frontdoor.recover) rests on.
"""
import os
import struct
import threading

import numpy as np
import pytest

from repro.serving.journal import (JournalWriter, Snapshot, fold_records,
                                   last_snapshot_record, load_snapshot,
                                   read_journal, save_snapshot)


def wal(tmp_path, name="wal.journal"):
    return os.path.join(tmp_path, name)


# ------------------------------------------------------------ framing ------

def test_append_read_round_trip(tmp_path):
    p = wal(tmp_path)
    w = JournalWriter(p, fsync_every=4)
    w.append("submit", rid=0, prompt=[1, 2, 3], max_new=8, arrival_s=0.0)
    w.append("token", rid=0, i=0, tok=[5])
    w.append("token", rid=0, i=1, tok=[6, 7])
    w.append("finish", rid=0, reason="completed")
    w.close()
    tail = read_journal(p)
    assert not tail.torn
    assert [r["t"] for r in tail.records] == ["submit", "token", "token",
                                              "finish"]
    assert [r["seq"] for r in tail.records] == [0, 1, 2, 3]
    assert tail.records[2]["tok"] == [6, 7]
    assert tail.valid_bytes == os.path.getsize(p)


def test_read_missing_file_is_empty(tmp_path):
    tail = read_journal(wal(tmp_path, "nope.journal"))
    assert tail.records == [] and not tail.torn and tail.last_seq == -1


def test_start_seq_continues_numbering(tmp_path):
    """Recovery reopens the journal with start_seq past the old tail so
    seqs stay monotonic across incarnations."""
    p = wal(tmp_path)
    w = JournalWriter(p)
    w.append("submit", rid=0, prompt=[1], max_new=2, arrival_s=0.0)
    w.close()
    w2 = JournalWriter(p, start_seq=read_journal(p).last_seq + 1)
    w2.append("finish", rid=0, reason="completed")
    w2.close()
    seqs = [r["seq"] for r in read_journal(p).records]
    assert seqs == [0, 1]


# ----------------------------------------------------- fsync batching ------

def test_token_records_batch_lifecycle_syncs_now(tmp_path):
    p = wal(tmp_path)
    w = JournalWriter(p, fsync_every=100)
    w.append("token", rid=0, i=0, tok=[1])
    w.append("token", rid=0, i=1, tok=[2])
    assert read_journal(p).records == []          # still buffered
    w.append("finish", rid=0, reason="completed")  # DURABLE_NOW -> flush
    assert len(read_journal(p).records) == 3
    w.close()


def test_writer_concurrent_appends_all_durable(tmp_path):
    """Caller threads (submit/cancel) and the serving thread append
    concurrently; without the writer's internal lock a record appended
    during another thread's flush() could vanish between the buffered
    write and the buffer clear — despite append() reporting it synced."""
    p = wal(tmp_path)
    w = JournalWriter(p, fsync_every=3)     # small batch: flushes collide

    def worker(tid):
        for i in range(40):
            w.append("token", rid=tid, i=i, tok=[tid])

    threads = [threading.Thread(target=worker, args=(t,)) for t in range(4)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    w.close()
    recs = read_journal(p).records
    assert len(recs) == 160                 # nothing dropped
    assert sorted(r["seq"] for r in recs) == list(range(160))
    for tid in range(4):                    # per-rid order preserved
        idx = [r["i"] for r in recs if r["rid"] == tid]
        assert idx == sorted(idx)


def test_abandon_loses_unflushed_tail(tmp_path):
    """abandon() models the crash: buffered records are gone, flushed
    ones survive. This is exactly the loss recovery must tolerate."""
    p = wal(tmp_path)
    w = JournalWriter(p, fsync_every=100)
    w.append("submit", rid=0, prompt=[1], max_new=4, arrival_s=0.0)  # syncs
    w.append("token", rid=0, i=0, tok=[9])     # buffered
    w.append("token", rid=0, i=1, tok=[8])     # buffered
    dropped = w.abandon()
    assert dropped == 2
    tail = read_journal(p)
    assert not tail.torn
    assert [r["t"] for r in tail.records] == ["submit"]


# ------------------------------------------------------ torn tolerance -----

def test_abandon_with_torn_prefix(tmp_path):
    """A crash mid-write leaves a strict prefix of one record on disk;
    the reader logs-and-skips it and keeps everything before."""
    p = wal(tmp_path)
    w = JournalWriter(p, fsync_every=100)
    w.append("submit", rid=0, prompt=[1, 2], max_new=4, arrival_s=0.0)
    w.append("token", rid=0, i=0, tok=[3])
    w.abandon(torn_bytes=5)
    tail = read_journal(p)
    assert tail.torn
    assert [r["t"] for r in tail.records] == ["submit"]
    assert tail.valid_bytes < os.path.getsize(p)


@pytest.mark.parametrize("cut", ["header", "payload"])
def test_truncated_final_record_skipped(tmp_path, cut):
    p = wal(tmp_path)
    w = JournalWriter(p)
    w.append("submit", rid=0, prompt=[1], max_new=4, arrival_s=0.0)
    w.append("finish", rid=0, reason="completed")
    w.close()
    size = os.path.getsize(p)
    full = read_journal(p)
    assert len(full.records) == 2
    # compute the last record's frame boundaries
    last_start = full.valid_bytes
    with open(p, "rb") as f:
        data = f.read()
    # find start of final record by re-walking
    off = 0
    while True:
        length, _ = struct.unpack_from("<II", data, off)
        end = off + 8 + length
        if end >= size:
            break
        off = end
    trunc = off + 3 if cut == "header" else off + 8 + 2
    with open(p, "r+b") as f:
        f.truncate(trunc)
    tail = read_journal(p)
    assert tail.torn
    assert [r["t"] for r in tail.records] == ["submit"]
    assert tail.valid_bytes == off
    assert last_start == size


def test_crc_mismatch_skipped(tmp_path):
    p = wal(tmp_path)
    w = JournalWriter(p)
    w.append("submit", rid=0, prompt=[1], max_new=4, arrival_s=0.0)
    w.append("finish", rid=0, reason="completed")
    w.close()
    with open(p, "r+b") as f:
        f.seek(-1, os.SEEK_END)
        last = f.read(1)
        f.seek(-1, os.SEEK_END)
        f.write(bytes([last[0] ^ 0xFF]))   # flip bits in final payload
    tail = read_journal(p)
    assert tail.torn
    assert [r["t"] for r in tail.records] == ["submit"]


# ---------------------------------------------------------- snapshots ------

def snap_fixture():
    return Snapshot(
        requests={
            0: {"prompt": np.array([1, 2, 3], np.int32),
                "tokens": [7, 8], "max_new": 8, "reason": None,
                "arrival_s": 0.0},
            1: {"prompt": np.array([4], np.int32), "tokens": [],
                "max_new": 4, "reason": "completed", "arrival_s": 0.5},
        },
        queue=[0], rng_key=np.array([0, 42], np.uint32),
        slot_rids=np.array([0, -1], np.int64),
        slot_cur_len=np.array([5, 0], np.int64),
        next_rid=2, seq=11, total_steps=3, round_idx=2)


def test_snapshot_round_trip(tmp_path):
    path = os.path.join(tmp_path, "snap")
    snap = snap_fixture()
    save_snapshot(path, snap)
    got = load_snapshot(path)
    assert got is not None
    assert set(got.requests) == {0, 1}
    np.testing.assert_array_equal(got.requests[0]["prompt"], [1, 2, 3])
    assert [int(t) for t in got.requests[0]["tokens"]] == [7, 8]
    assert got.requests[1]["tokens"] == []
    assert got.requests[1]["reason"] == "completed"
    assert got.queue == [0] and got.next_rid == 2 and got.seq == 11
    np.testing.assert_array_equal(got.rng_key, snap.rng_key)
    np.testing.assert_array_equal(got.slot_rids, [0, -1])
    assert got.slot_cur_len.dtype == np.int64


def test_snapshot_absent_or_corrupt_returns_none(tmp_path):
    assert load_snapshot(os.path.join(tmp_path, "missing")) is None
    bad = os.path.join(tmp_path, "bad")
    with open(bad + ".npz", "wb") as f:
        f.write(b"not a zipfile")
    assert load_snapshot(bad) is None          # logged, not raised


def test_snapshot_overwrite_is_atomic_no_tmp_left(tmp_path):
    path = os.path.join(tmp_path, "snap")
    save_snapshot(path, snap_fixture())
    save_snapshot(path, snap_fixture())        # overwrite the good one
    names = set(os.listdir(tmp_path))
    assert names == {"snap.npz", "snap.json"}  # no .tmp residue


# ------------------------------------------------------------ folding ------

def _recs():
    return [
        {"seq": 0, "t": "submit", "rid": 0, "prompt": [1, 2], "max_new": 4,
         "arrival_s": 0.0},
        {"seq": 1, "t": "token", "rid": 0, "i": 0, "tok": [5, 6]},
        {"seq": 2, "t": "token", "rid": 0, "i": 2, "tok": [7]},
        {"seq": 3, "t": "finish", "rid": 0, "reason": "completed"},
    ]


def test_fold_is_idempotent(tmp_path):
    once = fold_records(_recs())
    twice = fold_records(_recs() + _recs())
    assert once[0]["tokens"] == [5, 6, 7] == twice[0]["tokens"]
    assert once[0]["reason"] == "completed" == twice[0]["reason"]


def test_fold_over_snapshot_base_converges(tmp_path):
    """Replaying the FULL journal over a snapshot that already contains
    a prefix of the tokens converges (absolute token indices)."""
    base = Snapshot(requests={0: {"prompt": np.array([1, 2]),
                                  "tokens": [5], "max_new": 4,
                                  "reason": None, "arrival_s": 0.0}})
    table = fold_records(_recs(), base)
    assert table[0]["tokens"] == [5, 6, 7]


def test_fold_token_gap_poisons_rid_and_cancel_flag():
    """A mid-file gap is corruption, not a torn tail: the rid keeps its
    consistent prefix, later token records for it are ignored (they lie
    beyond the gap), and the entry is flagged for the recovery report."""
    recs = [
        {"seq": 0, "t": "submit", "rid": 1, "prompt": [9], "max_new": 8,
         "arrival_s": 0.0},
        {"seq": 1, "t": "token", "rid": 1, "i": 0, "tok": [5, 6]},
        {"seq": 2, "t": "token", "rid": 1, "i": 4, "tok": [1]},  # gap
        {"seq": 3, "t": "token", "rid": 1, "i": 5, "tok": [2]},  # poisoned
        {"seq": 4, "t": "token", "rid": 7, "i": 0, "tok": [1]},  # unknown
        {"seq": 5, "t": "cancel", "rid": 1},
    ]
    table = fold_records(recs)
    assert table[1]["tokens"] == [5, 6]        # consistent prefix kept
    assert table[1]["token_gap"] is True
    assert 7 not in table
    assert table[1].get("cancel_requested") is True


def test_last_snapshot_record():
    recs = [{"seq": 0, "t": "submit", "rid": 0, "prompt": [1],
             "max_new": 1, "arrival_s": 0.0},
            {"seq": 1, "t": "snapshot", "round": 1},
            {"seq": 2, "t": "snapshot", "round": 2}]
    assert last_snapshot_record(recs)["round"] == 2
    assert last_snapshot_record(recs[:1]) is None
