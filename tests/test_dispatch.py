"""Sorted grouped-GEMM dispatch vs the einsum reference vs the dense
oracle (kernels/ref.py): numerical equivalence across top_k, ragged
expert loads, masked continuous-batching slots, capacity drops, and
XShare-restricted selection — plus the structural invariants of the
dispatch plan itself (segment offsets, tile ownership, load metrics).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:
    # hypothesis isn't a hard dependency: deterministic mini-sampler
    # fallback (fixed draws) so the property tests run everywhere;
    # full random search wherever hypothesis is installed (CI).
    class _Strategy:
        def __init__(self, draw):
            self.draw = draw

    class st:  # noqa: N801 - mirrors the hypothesis module name
        @staticmethod
        def integers(lo, hi):
            return _Strategy(lambda rng: int(rng.integers(lo, hi + 1)))

        @staticmethod
        def sampled_from(xs):
            return _Strategy(lambda rng: xs[int(rng.integers(len(xs)))])

    def settings(**_kw):
        return lambda f: f

    def given(**strategies):
        def deco(f):
            def wrapper():
                rng = np.random.default_rng(0)
                for _ in range(8):
                    f(**{k: s.draw(rng) for k, s in strategies.items()})
            wrapper.__name__ = f.__name__
            wrapper.__doc__ = f.__doc__
            return wrapper
        return deco

from repro.configs.base import MoEConfig, XSharePolicy
from repro.kernels.ref import moe_ffn_ref
from repro.models import dispatch as DSP
from repro.models.moe import (OFF, expert_ffn, init_moe, moe_apply,
                              policy_max_active, route)

D = 16


def make_moe(E, k, f=32):
    return MoEConfig(num_experts=E, top_k=k, d_ff_expert=f)


def setup(T, E, k, seed=0):
    moe = make_moe(E, k)
    p = init_moe(jax.random.PRNGKey(seed), moe, D, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(seed + 1), (T, D))
    return moe, p, x


def ref_out(p, x, combine, E):
    return moe_ffn_ref(x, p["w1"], p["w3"], p["w2"], combine,
                       jnp.ones((E,), bool))


# ------------------------------------------------- three-way parity -------

@pytest.mark.parametrize("top_k", [1, 2])
@pytest.mark.parametrize("T,E", [(12, 8), (33, 4), (64, 16)])
def test_sorted_einsum_ref_three_way(T, E, top_k):
    moe, p, x = setup(T, E, top_k)
    idx, w, combine, _ = route(p, x, moe, OFF)
    y_sorted = expert_ffn(p, x, idx, w, moe, capacity=T, dispatch="sorted")
    y_einsum = expert_ffn(p, x, idx, w, moe, capacity=T, dispatch="einsum",
                          group_size=10**9)
    ref = ref_out(p, x, combine, E)
    np.testing.assert_allclose(np.asarray(y_sorted), np.asarray(ref),
                               atol=1e-4)
    np.testing.assert_allclose(np.asarray(y_sorted), np.asarray(y_einsum),
                               atol=1e-4)


def test_ragged_expert_loads():
    """Heavily skewed routing (one hot expert, several empty) — segments
    of wildly different sizes through the tile-padded layout."""
    moe, p, x = setup(24, 8, 2)
    # 20 tokens -> experts (0, 1); 4 tokens spread over (2..5); 6,7 empty
    idx = jnp.zeros((24, 2), jnp.int32).at[:, 1].set(1)
    idx = idx.at[20:, 0].set(jnp.array([2, 3, 4, 5]))
    idx = idx.at[20:, 1].set(jnp.array([3, 4, 5, 2]))
    w = jnp.full((24, 2), 0.5)
    one_hot = jax.nn.one_hot(idx, 8)
    combine = (one_hot * w[..., None]).sum(-2)
    y = expert_ffn(p, x, idx, w, moe, capacity=24, dispatch="sorted")
    np.testing.assert_allclose(np.asarray(y),
                               np.asarray(ref_out(p, x, combine, 8)),
                               atol=1e-4)


def test_token_mask_inactive_slots():
    """Masked slots (idx = -1, w = 0) consume no rows and produce zero
    output on every dispatch path."""
    moe, p, x = setup(16, 8, 2)
    tm = (jnp.arange(16) % 4) != 1
    ys = {}
    for mode in ("sorted", "einsum", "dense"):
        y, _ = moe_apply(p, x, moe, OFF, capacity=16, token_mask=tm,
                         dispatch=mode)
        assert bool(jnp.isfinite(y).all())
        assert float(jnp.abs(y[~tm]).max()) == 0.0, mode
        ys[mode] = np.asarray(y)
    np.testing.assert_allclose(ys["sorted"], ys["einsum"], atol=1e-4)
    np.testing.assert_allclose(ys["sorted"], ys["dense"], atol=1e-4)


def test_capacity_drops_match_einsum():
    """Per-expert clamp: stable sort keeps the first-in-batch tokens —
    exactly the single-group GShard drop set."""
    moe, p, x = setup(12, 8, 2)
    idx, w, _, _ = route(p, x, moe, OFF)
    for cap in (1, 2, 5):
        y_sorted = expert_ffn(p, x, idx, w, moe, capacity=cap,
                              dispatch="sorted")
        y_einsum = expert_ffn(p, x, idx, w, moe, capacity=cap,
                              dispatch="einsum", group_size=10**9,
                              min_capacity=1)
        np.testing.assert_allclose(np.asarray(y_sorted),
                                   np.asarray(y_einsum), atol=1e-4,
                                   err_msg=f"capacity={cap}")


@pytest.mark.parametrize("mode,kwargs", [
    ("batch", dict(k0=1, m_l=2)),
    ("ep", dict(k0=1, m_g=1, num_groups=4)),
    ("spec", dict(k0=1, m_l=0, m_r=2)),
])
def test_xshare_restricted_selection(mode, kwargs):
    """XShare masks shrink the routed set (zero-weight overflow entries,
    restricted experts) — sorted dispatch must agree with einsum and
    stay inside the policy_max_active bound."""
    moe, p, x = setup(12, 8, 2)
    pol = XSharePolicy(mode=mode, **kwargs)
    spec_shape = (3, 4) if mode == "spec" else None
    idx, w, combine, _ = route(p, x, moe, pol, spec_shape=spec_shape)
    y_sorted = expert_ffn(p, x, idx, w, moe, capacity=12, dispatch="sorted")
    y_einsum = expert_ffn(p, x, idx, w, moe, capacity=12, dispatch="einsum")
    np.testing.assert_allclose(np.asarray(y_sorted), np.asarray(y_einsum),
                               atol=1e-4)
    plan = DSP.dispatch_plan(idx, w, 8)
    occupied = int((plan.counts > 0).sum())
    assert occupied <= policy_max_active(pol, 12, 8, spec_shape=spec_shape)


# --------------------------------------------------- plan invariants ------

def test_plan_segments_and_tiles():
    idx = jnp.array([[0], [2], [0], [2], [2], [-1]], jnp.int32)
    w = jnp.array([[.5], [.5], [.5], [.5], [.5], [0.]], jnp.float32)
    plan = DSP.dispatch_plan(idx, w, 4, block_t=2)
    counts = np.asarray(plan.counts)
    np.testing.assert_array_equal(counts, [2, 0, 3, 0])
    # expert 0 pads to 2 rows, expert 2 to 4; dropped pair -> dest == P
    dest = np.asarray(plan.dest)
    s_w = np.asarray(plan.s_w)
    assert (dest[s_w > 0] < plan.padded_rows).all()
    assert (dest[s_w == 0] == plan.padded_rows).all()
    eids = np.asarray(plan.tile_eid)[np.asarray(plan.tile_valid) > 0]
    np.testing.assert_array_equal(eids, [0, 2, 2])
    # real per-group loads, not capacity padding
    np.testing.assert_array_equal(
        np.asarray(DSP.group_token_loads(plan.counts, 2)), [2, 3])


def test_plan_capacity_clamp_keeps_first():
    idx = jnp.zeros((5, 1), jnp.int32)
    w = jnp.full((5, 1), 1.0)
    plan = DSP.dispatch_plan(idx, w, 2, block_t=2, capacity=2)
    s_w = np.asarray(plan.s_w)
    assert s_w[:2].sum() == 2.0 and s_w[2:].sum() == 0.0
    assert int(plan.counts[0]) == 2


@given(T=st.integers(1, 40), E=st.sampled_from([2, 4, 8, 16]),
       k=st.integers(1, 3), seed=st.integers(0, 10**6))
@settings(max_examples=25, deadline=None)
def test_property_sorted_matches_ref(T, E, k, seed):
    k = min(k, E)
    moe, p, x = setup(T, E, k, seed=seed % 97)
    idx, w, combine, _ = route(p, x, moe, OFF)
    y = expert_ffn(p, x, idx, w, moe, capacity=T, dispatch="sorted")
    np.testing.assert_allclose(np.asarray(y),
                               np.asarray(ref_out(p, x, combine, E)),
                               atol=2e-4)


def test_grouped_kernel_path_matches_jnp_path():
    """Pallas grouped_ffn (interpret) == tile-gather einsum on the same
    plan — the serving hot-loop parity for the sorted pipeline."""
    moe, p, x = setup(16, 8, 2)
    idx, w, _, _ = route(p, x, moe, OFF)
    y_jnp = DSP.sorted_expert_ffn(x, p["w1"], p["w3"], p["w2"], idx, w,
                                  use_kernel=False)
    y_ker = DSP.sorted_expert_ffn(x, p["w1"], p["w3"], p["w2"], idx, w,
                                  use_kernel=True)
    np.testing.assert_allclose(np.asarray(y_jnp), np.asarray(y_ker),
                               atol=1e-4)
