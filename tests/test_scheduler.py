"""Continuous-batching serving subsystem (scheduler + fused decode step).

The load-bearing invariants:
  * lockstep equivalence — continuous batching with simultaneous
    arrivals reproduces the per-token host loop's tokens exactly;
  * cache integrity — a request admitted or evicted mid-stream decodes
    exactly as if it had the machine to itself (insert/evict surgery and
    the per-slot active mask never leak across slots);
  * fused-scan parity — N-token lax.scan decode == per-step decode.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import ARCHS
from repro.models import init_params
from repro.models.model import evict_slot, init_cache, insert_request
from repro.serving import Engine, Scheduler


def small(name, **kw):
    return ARCHS[name].reduced(num_layers=2, max_d_model=128,
                               max_vocab=256, **kw)


@pytest.fixture(scope="module")
def moe_setup():
    cfg = small("granite-moe-1b-a400m")
    params = init_params(cfg, jax.random.PRNGKey(1))
    prompts = np.asarray(jax.random.randint(
        jax.random.PRNGKey(2), (3, 12), 0, cfg.vocab_size))
    return cfg, params, prompts


# ------------------------------------------------- lockstep equivalence ---

def test_continuous_matches_lockstep_t0(moe_setup):
    """All requests at t=0 => token-exact vs. the seed per-token loop."""
    cfg, params, prompts = moe_setup
    eng = Engine(cfg, params, cache_len=128, decode_chunk=4)
    lock, st_l = eng.generate(prompts, 20, lockstep=True)
    cont, st_c = eng.generate(prompts, 20)
    assert np.array_equal(lock, cont)
    assert st_c.new_tokens == st_l.new_tokens
    assert st_c.layer_aux, "continuous path must keep XShare aux metrics"


def test_continuous_matches_lockstep_dense_window():
    """Rolling-window cache survives insert_request surgery."""
    cfg = small("h2o-danube-1.8b")
    assert cfg.attn.sliding_window
    params = init_params(cfg, jax.random.PRNGKey(3))
    prompts = np.asarray(jax.random.randint(
        jax.random.PRNGKey(4), (2, 10), 0, cfg.vocab_size))
    eng = Engine(cfg, params, cache_len=128, decode_chunk=8)
    lock, _ = eng.generate(prompts, 30, lockstep=True)
    cont, _ = eng.generate(prompts, 30)
    assert np.array_equal(lock, cont)


# ----------------------------------------- mid-stream admission/eviction --

def test_midstream_admission_cache_integrity(moe_setup):
    """num_slots < num_requests: later requests are admitted into slots
    vacated mid-stream (different max_new per request staggers
    completions). Every request must decode exactly as it does alone —
    any cross-slot cache leak or active-mask bug breaks this."""
    cfg, params, _ = moe_setup
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab_size, size=s).astype(np.int32)
               for s in (12, 12, 9, 15)]
    lens = [6, 14, 10, 8]

    eng = Engine(cfg, params, cache_len=128, decode_chunk=3)
    solo = [eng.generate(p[None], n)[0][0] for p, n in zip(prompts, lens)]

    sched = eng.make_scheduler(num_slots=2)
    for p, n in zip(prompts, lens):
        sched.submit(p, n)
    states = sched.run()
    assert all(s.status == "done" for s in states)
    for st, ref in zip(states, solo):
        assert np.array_equal(np.stack(st.tokens), ref), st.req.rid


def test_insert_evict_roundtrip(moe_setup):
    """Cache surgery unit: inserted row matches the prefilled source row;
    evict only zeroes that slot's cur_len."""
    cfg, params, prompts = moe_setup
    eng = Engine(cfg, params, cache_len=64)
    _, req_cache, _ = eng._prefill(params, prompts[1:2])
    batch = init_cache(cfg, 3, 64, jnp.float32)
    batch = insert_request(batch, req_cache, 2)
    assert np.asarray(batch["cur_len"]).tolist() == [0, 0, 12]
    np.testing.assert_array_equal(np.asarray(batch["kv_k"][:, 2]),
                                  np.asarray(req_cache["kv_k"][:, 0]))
    assert not np.asarray(batch["kv_k"][:, 0]).any()
    batch = evict_slot(batch, 2)
    assert np.asarray(batch["cur_len"]).tolist() == [0, 0, 0]


# ------------------------------------------------------ fused-scan parity --

def test_fused_chunk_size_invariance(moe_setup):
    """decode_steps_fused is a pure refactor of the per-step loop: the
    emitted tokens cannot depend on the scan chunk size."""
    cfg, params, prompts = moe_setup
    outs = []
    for chunk in (1, 5):
        eng = Engine(cfg, params, cache_len=128, decode_chunk=chunk)
        toks, _ = eng.generate(prompts, 17)
        outs.append(toks)
    assert np.array_equal(outs[0], outs[1])


def test_fused_masks_inactive_slots(moe_setup):
    """A partially-empty running batch must route exactly like the
    occupied rows alone: inactive slots are compute-masked out of MoE
    selection and the activation statistics, so the 4-slot/3-request
    run's per-step activated-expert counts equal the 3-slot run's."""
    cfg, params, prompts = moe_setup
    eng = Engine(cfg, params, cache_len=128, decode_chunk=4)
    acts = []
    for slots in (4, 3):                 # 3 requests either way
        sched = eng.make_scheduler(num_slots=slots)
        for b in range(prompts.shape[0]):
            sched.submit(prompts[b], 10)
        states = sched.run()
        lock, _ = eng.generate(prompts, 10, lockstep=True)
        for b, st in enumerate(states):
            assert np.array_equal(np.stack(st.tokens), lock[b])
        acts.append(np.array([np.asarray(a["activated_experts"])
                              for a in sched.step_aux]))
    np.testing.assert_array_equal(acts[0], acts[1])


# -------------------------------------------------- affinity admission ----

def test_affinity_admission_orders_by_overlap(moe_setup):
    """With a running batch in place, affinity admission pops the queued
    request with the most similar gate histogram, not the FIFO head."""
    cfg, params, prompts = moe_setup
    eng = Engine(cfg, params, cache_len=128, decode_chunk=2)
    sched = eng.make_scheduler(num_slots=2, admission="affinity")
    for b in range(prompts.shape[0]):
        sched.submit(prompts[b], 8)
    states = sched.run()
    assert all(s.status == "done" for s in states)
    assert all(s.gate_hist is not None and s.gate_hist.shape ==
               (cfg.moe.num_experts,) for s in states)
    # affinity scheduling must not corrupt decoding
    for st in states:
        solo, _ = eng.generate(st.req.prompt[None], 8)
        assert np.array_equal(np.stack(st.tokens), solo[0])


def test_gate_priors_stable_api(moe_setup):
    """Scheduler.gate_priors() — the stable per-slot expert-affinity
    read API (EP placement, affinity admission): (num_slots, E), rows
    mirror occupied slots' gate histograms, zeros elsewhere."""
    cfg, params, prompts = moe_setup
    eng = Engine(cfg, params, cache_len=128, decode_chunk=2)
    E = cfg.moe.num_experts
    captured = []
    sched = eng.make_scheduler(
        num_slots=2, admission="affinity",
        on_round=lambda s, r: captured.append(s.gate_priors()))
    for b in range(prompts.shape[0]):
        sched.submit(prompts[b], 6)
    # empty batch: correct shape, all zero
    assert sched.gate_priors().shape == (2, E)
    assert not sched.gate_priors().any()
    states = sched.run()
    assert all(s.status == "done" for s in states)
    assert captured
    for pri in captured:
        assert pri.shape == (2, E)
        assert np.isfinite(pri).all() and (pri >= 0).all()
    # a full batch mid-run carries a prior per occupied slot
    full = max(captured, key=lambda p: (p.sum(1) > 0).sum())
    assert (full.sum(1) > 0).all()
    # rows are the admission-time histograms the affinity path uses
    rows = {tuple(np.round(s.gate_hist, 12)) for s in states}
    for r in range(2):
        assert tuple(np.round(full[r], 12)) in rows


def test_scheduler_latency_accounting(moe_setup):
    cfg, params, prompts = moe_setup
    eng = Engine(cfg, params, cache_len=128, decode_chunk=2)
    sched = eng.make_scheduler(num_slots=3)
    for b in range(prompts.shape[0]):
        sched.submit(prompts[b], 6)
    states = sched.run()
    for st in states:
        assert 0.0 <= st.ttft_s <= st.latency_s
        assert len(st.tokens) == 6
        assert len(st.layer_aux) == 5    # tokens after the prefill token
