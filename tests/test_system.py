"""End-to-end system behaviour: train a small MoE LM on synthetic data,
then serve it under XShare policies and verify the paper's qualitative
claims hold on this system:

  1. batch-aware selection reduces activated experts vs vanilla top-k
     (Sec 3 / Fig 1 mechanism);
  2. eval quality degrades gracefully as the budget shrinks (Fig 4
     trade-off structure);
  3. hierarchical spec-mode selection (Alg 4) respects its budget
     structure on correlated speculative tokens (Sec 4);
  4. EP-aware selection bounds per-group load (Table 2 mechanism);
  5. captured gate mass grows monotonically with budget (the modular
     objective, Prop 3.2).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import (ArchConfig, AttnConfig, MoEConfig,
                                XSharePolicy)
from repro.data import SyntheticLM, batches
from repro.launch.train import make_train_step
from repro.models import forward, init_params, loss_fn
from repro.optim import adamw_init, cosine_schedule

CFG = ArchConfig(
    name="sys-moe", family="moe", num_layers=2, d_model=64, d_ff=0,
    vocab_size=128,
    attn=AttnConfig(num_heads=4, num_kv_heads=2, head_dim=16),
    moe=MoEConfig(num_experts=16, top_k=4, d_ff_expert=64),
)


@pytest.fixture(scope="module")
def trained():
    params = init_params(CFG, jax.random.PRNGKey(0))
    opt = adamw_init(params)
    step = jax.jit(make_train_step(CFG, lr=cosine_schedule(3e-3, 5, 60),
                                   remat=False, capacity_factor=4.0))
    lm = SyntheticLM(CFG.vocab_size, name="sys", branch=4)
    stream = batches(lm, batch=8, seq_len=64, seed=0)
    for _ in range(60):
        params, opt, m = step(params, opt, jnp.asarray(next(stream)))
    eval_toks = jnp.asarray(next(batches(lm, batch=16, seq_len=64,
                                         seed=99)))
    return params, eval_toks


def eval_loss(params, toks, policy):
    return float(loss_fn(CFG, params, toks, policy=policy, remat=False,
                         capacity_factor=16.0, lb_weight=0.0)[0])


def layer_activation(params, toks, policy, spec_shape=None):
    _, aux = forward(CFG, params, toks, policy=policy,
                     spec_shape=spec_shape, capacity_factor=16.0)
    return float(np.mean(np.asarray(aux["activated_experts"])))


def test_batch_selection_reduces_activation(trained):
    params, toks = trained
    dec = toks[:, :2]
    base = layer_activation(params, dec, XSharePolicy(mode="off"))
    shared = layer_activation(
        params, dec, XSharePolicy(mode="batch", k0=1, m_l=2))
    assert shared < base, (base, shared)


def test_quality_budget_tradeoff(trained):
    params, toks = trained
    base = eval_loss(params, toks, XSharePolicy(mode="off"))
    rich = eval_loss(params, toks,
                     XSharePolicy(mode="batch", k0=2, m_l=12))
    poor = eval_loss(params, toks,
                     XSharePolicy(mode="batch", k0=0, m_l=1))
    assert rich - base < 0.2, (base, rich)
    assert poor >= rich - 1e-6, (rich, poor)


def test_spec_mode_budget_structure(trained):
    params, _ = trained
    lm = SyntheticLM(CFG.vocab_size, name="sys", branch=4)
    reqs = jnp.asarray(lm.sample(np.random.default_rng(5), 4, 4))
    pol = XSharePolicy(mode="spec", k0=1, m_l=0, m_r=2)
    act = layer_activation(params, reqs, pol, spec_shape=(4, 4))
    base = layer_activation(params, reqs, XSharePolicy(mode="off"))
    assert act <= base
    _, aux = forward(CFG, params, reqs, policy=pol, spec_shape=(4, 4),
                     capacity_factor=16.0)
    assert float(np.max(np.asarray(aux["selected_set"]))) <= 16


def test_ep_mode_bounds_group_load(trained):
    params, toks = trained
    pol = XSharePolicy(mode="ep", k0=1, m_g=2, num_groups=4)
    _, aux = forward(CFG, params, toks[:, :4], policy=pol,
                     capacity_factor=16.0)
    assert float(np.max(np.asarray(aux["max_group_load"]))) <= 2


def test_gate_mass_increases_with_budget(trained):
    params, toks = trained
    dec = toks[:, :2]
    masses = []
    for m_l in (1, 4, 12):
        _, aux = forward(CFG, params, dec,
                         policy=XSharePolicy(mode="batch", k0=1, m_l=m_l),
                         capacity_factor=16.0)
        masses.append(float(np.mean(np.asarray(aux["gate_mass"]))))
    assert masses[0] <= masses[1] <= masses[2] <= 1.0 + 1e-6
