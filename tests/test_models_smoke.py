"""Per-assigned-architecture smoke tests: REDUCED variants (2 layers,
d_model <= 512, <= 4 experts) run one forward + one train step + one
decode step on CPU, asserting shapes and finiteness. The FULL configs are
exercised only via the dry-run."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import ARCHS, assigned_names
from repro.models import (decode_step, forward, init_params, loss_fn,
                          prefill)
from repro.optim import adamw_init, adamw_update

ALL_ARCHS = assigned_names() + ["gpt-oss-120b-proxy", "deepseek-r1-proxy"]


def _toks(cfg, key, B, S):
    if cfg.family == "audio":
        return jax.random.randint(key, (B, S, cfg.num_codebooks), 0,
                                  cfg.vocab_size)
    return jax.random.randint(key, (B, S), 0, cfg.vocab_size)


def _prefix(cfg, key, B):
    if cfg.prefix_len:
        return jax.random.normal(key, (B, cfg.prefix_len, cfg.d_model))
    return None


@pytest.mark.parametrize("name", ALL_ARCHS)
def test_reduced_forward_and_train_step(name):
    cfg = ARCHS[name].reduced()
    assert cfg.num_layers == 2 and cfg.d_model <= 512
    if cfg.moe:
        assert cfg.moe.num_experts <= 4
    key = jax.random.PRNGKey(0)
    params = init_params(cfg, key)
    B, S = 2, 32
    toks = _toks(cfg, key, B, S)
    pe = _prefix(cfg, key, B)

    logits, _ = forward(cfg, params, toks, prefix_embeds=pe)
    total = S + cfg.prefix_len
    if cfg.family == "audio":
        assert logits.shape == (B, total, cfg.num_codebooks,
                                cfg.padded_vocab)
    else:
        assert logits.shape == (B, total, cfg.padded_vocab)
    assert bool(jnp.isfinite(logits).all()), "NaN/Inf in logits"

    # one full train step (fwd + bwd + AdamW)
    opt = adamw_init(params)

    def lf(p):
        return loss_fn(cfg, p, toks, prefix_embeds=pe, remat=False)[0]

    loss, grads = jax.value_and_grad(lf)(params)
    assert bool(jnp.isfinite(loss))
    gleaves = jax.tree_util.tree_leaves(grads)
    assert all(bool(jnp.isfinite(g).all()) for g in gleaves)
    new_params, opt = adamw_update(grads, opt, params, lr=1e-3)
    # params actually moved
    moved = any(
        float(jnp.abs(a - b).max()) > 0
        for a, b in zip(jax.tree_util.tree_leaves(new_params),
                        jax.tree_util.tree_leaves(params)))
    assert moved


@pytest.mark.parametrize("name", ALL_ARCHS)
def test_reduced_prefill_decode_consistency(name):
    """prefill + decode_step logits == full forward logits (the core
    serving-correctness invariant), drop-free MoE capacity."""
    cfg = ARCHS[name].reduced()
    key = jax.random.PRNGKey(1)
    params = init_params(cfg, key)
    B, S = 2, 17
    toks = _toks(cfg, key, B, S)
    pe = _prefix(cfg, key, B)
    full, _ = forward(cfg, params, toks, prefix_embeds=pe,
                      capacity_factor=99.0)
    last, cache, _ = prefill(cfg, params, toks[:, :S - 1], cache_len=64,
                             prefix_embeds=pe, capacity_factor=99.0)
    P = cfg.prefix_len
    np.testing.assert_allclose(np.asarray(last),
                               np.asarray(full[:, P + S - 2]), atol=3e-4)
    dec, cache, _ = decode_step(cfg, params, toks[:, S - 1:S], cache,
                                capacity_factor=99.0)
    np.testing.assert_allclose(np.asarray(dec[:, 0]),
                               np.asarray(full[:, P + S - 1]), atol=3e-4)
    assert (np.asarray(cache["cur_len"]) == P + S).all()


def test_moe_arch_runs_with_xshare_policy():
    from repro.configs.base import XSharePolicy
    cfg = ARCHS["qwen3-moe-235b-a22b"].reduced()
    params = init_params(cfg, jax.random.PRNGKey(0))
    toks = _toks(cfg, jax.random.PRNGKey(2), 2, 16)
    _, cache, _ = prefill(cfg, params, toks, cache_len=64)
    pol = XSharePolicy(mode="batch", k0=1, m_l=1)
    lg, cache, aux = decode_step(cfg, params, toks[:, -1:], cache,
                                 policy=pol)
    assert bool(jnp.isfinite(lg).all())
    assert "activated_experts" in aux
    E = cfg.moe.num_experts
    assert int(np.max(aux["selected_set"])) <= E


def test_window_arch_long_context_decode():
    """Forced-window decode runs beyond the window size (the long_500k
    mechanism) and matches windowed full-forward."""
    cfg = dataclasses.replace(ARCHS["llama3-8b"].reduced(), )
    params = init_params(cfg, jax.random.PRNGKey(0))
    B, S, W = 1, 40, 16
    toks = jax.random.randint(jax.random.PRNGKey(3), (B, S), 0,
                              cfg.vocab_size)
    full, _ = forward(cfg, params, toks, window=W)
    last, cache, _ = prefill(cfg, params, toks[:, :S - 1], cache_len=S + 8,
                             force_window=W)
    np.testing.assert_allclose(np.asarray(last),
                               np.asarray(full[:, S - 2]), atol=3e-4)
    dec, _, _ = decode_step(cfg, params, toks[:, S - 1:], cache,
                            force_window=W)
    np.testing.assert_allclose(np.asarray(dec[:, 0]),
                               np.asarray(full[:, S - 1]), atol=3e-4)
