"""Mamba2 SSD layer: chunked scan vs sequential recurrence, prefill ->
decode state handoff, conv cache continuity."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import SSMConfig
from repro.kernels.ref import ssd_chunk_ref
from repro.models.ssm import (init_ssm, ssd_chunked, ssm_decode,
                              ssm_forward)

CFG = SSMConfig(d_state=16, d_conv=4, expand=2, head_dim=16, n_groups=1,
                chunk_size=8)
D = 64


@pytest.mark.parametrize("S,chunk", [(32, 8), (40, 16), (7, 8), (64, 64)])
def test_ssd_chunked_matches_sequential(S, chunk):
    B, nh, hd, ds = 2, 4, 16, 12
    ks = jax.random.split(jax.random.PRNGKey(S), 5)
    x = jax.random.normal(ks[0], (B, S, nh, hd)) * 0.5
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, nh)))
    A = -jnp.exp(jax.random.normal(ks[2], (nh,)) * 0.3)
    Bm = jax.random.normal(ks[3], (B, S, 1, ds)) * 0.3
    Cm = jax.random.normal(ks[4], (B, S, 1, ds)) * 0.3
    y, st = ssd_chunked(x, dt, A, Bm, Cm, chunk)
    Bh = jnp.repeat(Bm, nh, 2)
    Ch = jnp.repeat(Cm, nh, 2)
    yr, sr = ssd_chunk_ref(x, dt, A, Bh, Ch)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr), atol=1e-4)
    np.testing.assert_allclose(np.asarray(st), np.asarray(sr), atol=1e-4)


def test_ssd_chunked_init_state_continuation():
    """Processing [a;b] at once == processing a, then b from a's state."""
    B, S, nh, hd, ds = 1, 24, 2, 16, 8
    ks = jax.random.split(jax.random.PRNGKey(5), 5)
    x = jax.random.normal(ks[0], (B, S, nh, hd)) * 0.5
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, nh)))
    A = -jnp.exp(jax.random.normal(ks[2], (nh,)) * 0.3)
    Bm = jax.random.normal(ks[3], (B, S, 1, ds)) * 0.3
    Cm = jax.random.normal(ks[4], (B, S, 1, ds)) * 0.3
    y_all, st_all = ssd_chunked(x, dt, A, Bm, Cm, 8)
    cut = 16
    y1, st1 = ssd_chunked(x[:, :cut], dt[:, :cut], A, Bm[:, :cut],
                          Cm[:, :cut], 8)
    y2, st2 = ssd_chunked(x[:, cut:], dt[:, cut:], A, Bm[:, cut:],
                          Cm[:, cut:], 8, init_state=st1)
    np.testing.assert_allclose(np.asarray(y_all[:, cut:]), np.asarray(y2),
                               atol=1e-4)
    np.testing.assert_allclose(np.asarray(st_all), np.asarray(st2),
                               atol=1e-4)


def test_ssm_block_prefill_then_decode_matches_full():
    """Layer-level: forward over S tokens == forward over S-3 + 3 decode
    recurrence steps using the (conv, state) cache."""
    B, S = 2, 19
    p = init_ssm(jax.random.PRNGKey(0), CFG, D, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (B, S, D)) * 0.5
    y_full, _ = ssm_forward(p, x, CFG, D, 1e-5)
    cut = S - 3
    y1, cache = ssm_forward(p, x[:, :cut], CFG, D, 1e-5)
    np.testing.assert_allclose(np.asarray(y_full[:, :cut]),
                               np.asarray(y1), atol=1e-4)
    conv, state = cache
    outs = []
    for t in range(cut, S):
        y_t, (conv, state) = ssm_decode(p, x[:, t], (conv, state), CFG, D,
                                        1e-5)
        outs.append(y_t)
    got = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(y_full[:, cut:]),
                               np.asarray(got), atol=1e-4)


def test_ssm_kernel_path_matches_jnp_path():
    p = init_ssm(jax.random.PRNGKey(0), CFG, D, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(2), (2, 32, D)) * 0.5
    y1, (c1, s1) = ssm_forward(p, x, CFG, D, 1e-5)
    y2, (c2, s2) = ssm_forward(p, x, CFG, D, 1e-5, use_kernel=True)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=1e-4)
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2), atol=1e-4)
