"""Serving robustness: fault campaigns, deadlines, cancellation,
admission control, degradation, numerics quarantine.

The load-bearing guarantees:
  * under every injected fault class the scheduler drains or sheds all
    requests with ZERO slot leaks (invariant checker clean);
  * requests unaffected by a fault produce tokens bit-identical to a
    fault-free greedy run;
  * admission control and deadline expiry shed with structured reasons
    and never stall the machine.
"""
import time

import jax
import numpy as np
import pytest

from repro.configs.registry import ARCHS
from repro.models import init_params
from repro.serving import (Engine, Fault, FaultInjector, InvalidRequest,
                           QueueFull, sample_campaign)
from repro.serving.errors import (REASON_CANCELLED, REASON_COMPLETED,
                                  REASON_DEADLINE_E2E, REASON_DEADLINE_TTFT,
                                  REASON_FAULT, REASON_NUMERICS,
                                  REASON_SHED_QUEUE, REASON_WALL,
                                  InvariantViolation)
from repro.serving.sampler import sample
from repro.serving.scheduler import tighten_policy
from repro.configs.base import XSharePolicy


def small(name, **kw):
    return ARCHS[name].reduced(num_layers=2, max_d_model=128,
                               max_vocab=256, **kw)


@pytest.fixture(scope="module")
def moe_setup():
    cfg = small("granite-moe-1b-a400m")
    params = init_params(cfg, jax.random.PRNGKey(1))
    prompts = np.asarray(jax.random.randint(
        jax.random.PRNGKey(2), (3, 12), 0, cfg.vocab_size))
    return cfg, params, prompts


@pytest.fixture(scope="module")
def engine(moe_setup):
    cfg, params, _ = moe_setup
    return Engine(cfg, params, cache_len=128, decode_chunk=4)


def drained(sched):
    """Zero slot leaks: every slot free, nothing queued, all terminal."""
    assert all(s is None for s in sched._slots)
    assert not sched._active.any()
    assert not sched._queue and not sched._incoming
    assert all(st.status in ("done", "shed") for st in sched._states)
    sched.check_invariants()


# ------------------------------------------------------- input validation --

def test_submit_validation(engine, moe_setup):
    _, _, prompts = moe_setup
    sched = engine.make_scheduler(num_slots=2)
    with pytest.raises(InvalidRequest):
        sched.submit(prompts[0], 0)                      # max_new < 1
    with pytest.raises(ValueError):                      # is-a ValueError
        sched.submit(prompts[0], 0)
    with pytest.raises(InvalidRequest):
        sched.submit(prompts[0], 200)                    # 12+200-1 > 128
    with pytest.raises(InvalidRequest):
        sched.submit(np.zeros((0,), np.int32), 4)        # empty prompt
    assert not sched._states                             # nothing recorded


def test_generate_validation(engine, moe_setup):
    _, _, prompts = moe_setup
    with pytest.raises(InvalidRequest):
        engine.generate(prompts, 0)
    with pytest.raises(InvalidRequest):
        engine.generate(prompts, 500)


# --------------------------------------------------------- sampler guard --

def test_sampler_nonfinite_guard():
    key = jax.random.PRNGKey(0)
    logits = np.full((4, 16), -1.0, np.float32)
    logits[:, 3] = 5.0
    bad = logits.copy()
    bad[1, 0] = np.nan
    bad[2, 5] = np.inf
    toks = np.asarray(sample(bad, key, temperature=0.7, top_p=0.9))
    assert ((0 <= toks) & (toks < 16)).all()
    # rows without non-finite entries sample identically
    clean = np.asarray(sample(logits, key, temperature=0.7, top_p=0.9))
    assert toks[0] == clean[0] and toks[3] == clean[3]
    # greedy path is bit-identical to plain argmax (untouched)
    g = np.asarray(sample(logits, key, temperature=0.0))
    np.testing.assert_array_equal(g, logits.argmax(-1))


# ---------------------------------------------------- numerics quarantine --

def test_nan_quarantine_cobatch_exact(engine, moe_setup):
    """NaN logits on slot 1 at global step 5: that request alone is shed
    (reason numerics), co-batched requests are token-exact vs. the
    fault-free run, and the freed slot serves a later request."""
    cfg, params, prompts = moe_setup
    free, _ = engine.generate(prompts, 12)               # fault-free ref

    inj = FaultInjector([Fault("nan_logits", slot=1, step=5)])
    sched = engine.make_scheduler(num_slots=3, faults=inj,
                                  invariants=True)
    for b in range(3):
        sched.submit(prompts[b], 12)
    late = sched.submit(prompts[1], 12, arrival_s=0.0)   # reuses the slot
    states = sched.run()
    drained(sched)
    assert [("nan_logits", 1, 5.0)] == [e for e in inj.log
                                        if e[0] == "nan_logits"]
    poisoned = states[1]
    assert poisoned.status == "shed"
    assert poisoned.finish_reason == REASON_NUMERICS
    # 1 prefill token + 5 fused steps before the poisoned step
    assert len(poisoned.tokens) == 6
    np.testing.assert_array_equal(np.stack(poisoned.tokens), free[1][:6])
    # co-batched requests: bit-identical to the fault-free run
    for b in (0, 2):
        np.testing.assert_array_equal(np.stack(states[b].tokens), free[b])
    # the re-submitted copy of request 1 (served on a fresh slot after
    # quarantine scrubbed it) decodes exactly
    assert late.status == "done"
    np.testing.assert_array_equal(np.stack(late.tokens), free[1])


# ----------------------------------------------------- insert-fault retry --

def test_insert_fault_transient_recovers(engine, moe_setup):
    """Staggered arrivals (no whole-batch fast path) so rid 1 goes
    through insert_request; two injected failures sit inside the retry
    budget and the request completes token-exact."""
    cfg, params, prompts = moe_setup
    free, _ = engine.generate(prompts, 10)
    inj = FaultInjector([Fault("insert_fail", rid=1, times=2)])
    sched = engine.make_scheduler(num_slots=2, faults=inj, invariants=True,
                                  max_retries=3, retry_backoff_s=0.001)
    for b in range(3):
        sched.submit(prompts[b], 10, arrival_s=0.01 * b)
    states = sched.run()
    drained(sched)
    assert sched.retries >= 2
    assert all(st.status == "done" for st in states)
    for b, st in enumerate(states):
        np.testing.assert_array_equal(np.stack(st.tokens), free[b])


def test_insert_fault_permanent_sheds(engine, moe_setup):
    """Failures past the retry budget shed ONLY the afflicted request;
    the others complete exactly and admission keeps flowing."""
    cfg, params, prompts = moe_setup
    free, _ = engine.generate(prompts, 10)
    inj = FaultInjector([Fault("insert_fail", rid=1, times=99)])
    sched = engine.make_scheduler(num_slots=2, faults=inj, invariants=True,
                                  max_retries=2, retry_backoff_s=0.001)
    for b in range(3):
        sched.submit(prompts[b], 10, arrival_s=0.01 * b)
    states = sched.run()
    drained(sched)
    assert states[1].status == "shed"
    assert states[1].finish_reason == REASON_FAULT
    for b in (0, 2):
        assert states[b].status == "done"
        np.testing.assert_array_equal(np.stack(states[b].tokens), free[b])


# ------------------------------------------------- watchdog / slow paths --

@pytest.mark.slow          # wall-clock-sensitive: asserts on real delays
def test_watchdog_counts_stalls(engine, moe_setup):
    cfg, params, prompts = moe_setup
    inj = FaultInjector([Fault("slow_prefill", rid=0, delay_s=0.05),
                         Fault("stall_decode", step=1, delay_s=0.05)])
    sched = engine.make_scheduler(num_slots=2, faults=inj, invariants=True,
                                  watchdog_s=0.03)
    for b in range(2):
        sched.submit(prompts[b], 8, arrival_s=0.01 * b)
    states = sched.run()
    drained(sched)
    assert all(st.status == "done" for st in states)
    assert sched.stall_events >= 2
    kinds = {e[0] for e in inj.log}
    assert {"slow_prefill", "stall_decode"} <= kinds


# ------------------------------------------------------------- cancel ----

def test_cancel_queued(engine, moe_setup):
    _, _, prompts = moe_setup
    sched = engine.make_scheduler(num_slots=1, invariants=True)
    a = sched.submit(prompts[0], 6)
    b = sched.submit(prompts[1], 6)
    assert sched.cancel(b.req.rid)
    assert b.status == "shed" and b.finish_reason == REASON_CANCELLED
    assert not sched.cancel(b.req.rid)        # already terminal
    assert not sched.cancel(12345)            # unknown rid
    sched.run()
    drained(sched)
    assert a.status == "done" and len(a.tokens) == 6
    assert not b.tokens


def test_cancel_mid_decode(engine, moe_setup):
    """Cancellation from the on_round hook evicts the slot mid-stream:
    the victim keeps its partial tokens (still exact), survivors and the
    request admitted into the freed slot are token-exact."""
    cfg, params, prompts = moe_setup
    free, _ = engine.generate(prompts, 12)

    def hook(s, round_idx):
        if round_idx == 2:
            s.cancel(1)
    sched = engine.make_scheduler(num_slots=2, invariants=True,
                                  on_round=hook)
    for b in range(3):
        sched.submit(prompts[b], 12)
    states = sched.run()
    drained(sched)
    victim = states[1]
    assert victim.status == "shed"
    assert victim.finish_reason == REASON_CANCELLED
    assert 0 < len(victim.tokens) < 12
    np.testing.assert_array_equal(np.stack(victim.tokens),
                                  free[1][:len(victim.tokens)])
    for b in (0, 2):
        assert states[b].status == "done"
        np.testing.assert_array_equal(np.stack(states[b].tokens), free[b])


# ------------------------------------------------------------ deadlines --

def test_ttft_deadline_sheds_without_stalling(engine, moe_setup):
    """One slot, a long hog, and two requests whose TTFT budget expires
    while queued: they shed (reason deadline_ttft) and the deadline-free
    request behind them is still admitted and completes."""
    _, _, prompts = moe_setup
    sched = engine.make_scheduler(num_slots=1, invariants=True)
    hog = sched.submit(prompts[0], 48)
    d1 = sched.submit(prompts[1], 8, ttft_deadline_s=1e-4)
    d2 = sched.submit(prompts[2], 8, ttft_deadline_s=1e-4)
    ok = sched.submit(prompts[1], 8)
    states = sched.run()
    drained(sched)
    assert hog.status == "done" and len(hog.tokens) == 48
    for d in (d1, d2):
        assert d.status == "shed"
        assert d.finish_reason == REASON_DEADLINE_TTFT
        assert not d.tokens
    assert ok.status == "done" and len(ok.tokens) == 8
    assert sched.reason_counts()[REASON_DEADLINE_TTFT] == 2


def test_e2e_deadline_evicts_mid_decode(engine, moe_setup):
    """A running request whose end-to-end budget expires mid-decode is
    evicted between fused rounds (the budget is tightened from the
    on_round hook so the expiry instant is deterministic)."""
    _, _, prompts = moe_setup
    sched = engine.make_scheduler(num_slots=2, invariants=True)
    doomed = sched.submit(prompts[0], 100, deadline_s=60.0)
    okreq = sched.submit(prompts[1], 8)

    def hook(s, round_idx):
        if round_idx == 2:
            doomed.req.deadline_s = -1.0   # now > arrival + deadline
    sched.on_round = hook
    sched.run()
    drained(sched)
    assert doomed.status == "shed"
    assert doomed.finish_reason == REASON_DEADLINE_E2E
    assert 0 < len(doomed.tokens) < doomed.req.max_new_tokens
    assert okreq.status == "done" and len(okreq.tokens) == 8


# ------------------------------------------------- bounded-queue admission --

def test_bounded_queue_reject_and_shed(engine, moe_setup):
    _, _, prompts = moe_setup
    sched = engine.make_scheduler(num_slots=1, max_queue=2,
                                  overload="reject")
    sched.submit(prompts[0], 4)
    sched.submit(prompts[1], 4)
    with pytest.raises(QueueFull):
        sched.submit(prompts[2], 4)
    assert len(sched._states) == 2            # rejected request not recorded

    shed = engine.make_scheduler(num_slots=1, max_queue=2, overload="shed",
                                 invariants=True)
    shed.submit(prompts[0], 4)
    shed.submit(prompts[1], 4)
    third = shed.submit(prompts[2], 4)
    assert third.status == "shed"
    assert third.finish_reason == REASON_SHED_QUEUE
    states = shed.run()
    drained(shed)
    assert [st.status for st in states] == ["done", "done", "shed"]


# ------------------------------------------------------ degradation ladder --

def test_degradation_ladder_escalates_and_recovers(engine, moe_setup):
    """Queue pressure >= hi escalates (affinity falls back to FCFS and
    the XShare budget tightens); the ladder recovers to level 0 as the
    queue drains, and every request still completes."""
    _, _, prompts = moe_setup
    sched = engine.make_scheduler(num_slots=1, admission="affinity",
                                  degrade=True, degrade_hi=1.0,
                                  degrade_lo=0.0, invariants=True)
    reqs = [sched.submit(prompts[b % 3], 6) for b in range(6)]
    levels = []
    sched.on_round = lambda s, i: levels.append(s.level)
    states = sched.run()
    drained(sched)
    assert all(st.status == "done" for st in states)
    assert max(levels) >= 1                   # escalated under pressure
    # recovery began once the queue drained (run() may exit before the
    # ladder steps all the way back to 0 — one decrement per idle loop)
    assert sched.level < max(levels)
    lvls = [lvl for _, lvl in sched.degrade_events]
    assert any(b < a for a, b in zip(lvls, lvls[1:]))   # a down-step
    # under escalation, affinity admission fell back to FCFS
    assert sched.admission == "affinity"
    sched.level = max(levels)
    assert sched.admission_effective == "fcfs"
    sched.level = 0
    assert sched.admission_effective == "affinity"


def test_tighten_policy_shrinks_budget(moe_setup):
    cfg, _, _ = moe_setup
    from repro.models.moe import policy_max_active
    off = XSharePolicy(mode="off")
    assert policy_max_active(off, 1, cfg.moe.num_experts) == \
        cfg.moe.num_experts                   # OFF: no bound to tighten
    for lvl in (1, 2):
        t = tighten_policy(off, lvl, cfg.moe)
        assert t.mode == "batch"
        assert policy_max_active(t, 1, cfg.moe.num_experts) < \
            cfg.moe.num_experts
    b = XSharePolicy(mode="batch", k0=1, m_l=8)
    assert tighten_policy(b, 1, cfg.moe).m_l == 4
    assert tighten_policy(b, 2, cfg.moe).m_l == 2
    assert tighten_policy(b, 0, cfg.moe) is b
    ep = XSharePolicy(mode="ep", m_g=4, num_groups=4)
    assert tighten_policy(ep, 2, cfg.moe).m_g == 1


# ------------------------------------------------------------- run guard --

def test_run_max_wall_sheds_everything(engine, moe_setup):
    _, _, prompts = moe_setup
    sched = engine.make_scheduler(num_slots=1, invariants=True)
    for b in range(3):
        sched.submit(prompts[b], 6, arrival_s=30.0 + b)  # far future
    t0 = time.perf_counter()
    states = sched.run(max_wall_s=0.2)
    assert time.perf_counter() - t0 < 5.0
    drained(sched)
    assert all(st.status == "shed" and st.finish_reason == REASON_WALL
               for st in states)


# --------------------------------------------------------- invariant trips --

def test_invariant_checker_catches_corruption(engine, moe_setup):
    _, _, prompts = moe_setup
    sched = engine.make_scheduler(num_slots=2, admission="affinity")
    for b in range(2):
        sched.submit(prompts[b], 4)
    sched.run()
    sched.check_invariants()                  # clean after drain
    sched._batch_mass += 1.0                  # corrupt mass accounting
    sched._slots[0] = sched._states[0]        # fake an occupied slot
    sched._states[0].history.append("waiting")  # illegal recorded edge
    with pytest.raises(InvariantViolation):
        sched.check_invariants()


# ----------------------------------------------------- seeded campaign ----

def test_seeded_campaign_reproducible_and_leak_free(engine, moe_setup):
    """A seeded mixed campaign over Poisson-ish staggered traffic:
    deterministic plan, full drain, zero slot leaks, invariants clean,
    and every terminal state carries a structured reason."""
    _, _, prompts = moe_setup
    camp = sample_campaign(25, num_requests=5, num_slots=2,
                           horizon_steps=20, delay_s=0.01)
    again = sample_campaign(25, num_requests=5, num_slots=2,
                            horizon_steps=20, delay_s=0.01)
    assert camp.faults == again.faults        # same seed, same plan
    assert {f.kind for f in camp.faults} >= \
        {"slow_prefill", "nan_logits", "insert_fail"}   # mixed campaign
    sched = engine.make_scheduler(num_slots=2, faults=camp,
                                  invariants=True, watchdog_s=0.005,
                                  max_retries=2, retry_backoff_s=0.001)
    for i in range(5):
        sched.submit(prompts[i % 3], 8, arrival_s=0.005 * i)
    states = sched.run(max_wall_s=60.0)
    drained(sched)
    reasons = sched.reason_counts()
    assert sum(reasons.values()) == 5
    assert set(reasons) <= {REASON_COMPLETED, REASON_NUMERICS, REASON_FAULT}


def test_campaign_outcomes_deterministic(engine, moe_setup):
    """Same sample_campaign seed -> identical survival/reason counts
    across two independent serves. No deadlines and no watchdog in the
    loop, so the outcome depends only on the (deterministic) fault plan
    — not on wall-clock speed."""
    _, _, prompts = moe_setup
    counts = []
    for _ in range(2):
        camp = sample_campaign(25, num_requests=5, num_slots=2,
                               horizon_steps=20, delay_s=0.0)
        sched = engine.make_scheduler(num_slots=2, faults=camp,
                                      invariants=True, max_retries=2,
                                      retry_backoff_s=0.0)
        for i in range(5):
            sched.submit(prompts[i % 3], 8)
        sched.run(max_wall_s=60.0)
        drained(sched)
        counts.append(dict(sched.reason_counts()))
    assert counts[0] == counts[1]
    assert sum(counts[0].values()) == 5


def test_crash_campaign_plan_deterministic():
    """Crash-fault sampling (p_crash) replays bit-identically and pairs
    crash_mid_round with an optional journal_torn_write; existing seeds
    keep their exact pre-crash plans (crash draws come last)."""
    a = sample_campaign(3, num_requests=4, num_slots=2, horizon_steps=16,
                        p_crash=1.0)
    b = sample_campaign(3, num_requests=4, num_slots=2, horizon_steps=16,
                        p_crash=1.0)
    assert a.faults == b.faults
    kinds = [f.kind for f in a.faults]
    assert "crash_mid_round" in kinds
    assert kinds.index("crash_mid_round") > max(
        (i for i, k in enumerate(kinds) if k in
         ("slow_prefill", "nan_logits", "insert_fail", "stall_decode")),
        default=-1)
    # p_crash=0 (the default) leaves the legacy plan untouched
    legacy = sample_campaign(3, num_requests=4, num_slots=2,
                             horizon_steps=16)
    assert legacy.faults == [f for f in a.faults
                             if f.kind not in ("crash_mid_round",
                                               "journal_torn_write")]
