"""Data pipeline / optimizer / checkpoint substrate tests."""
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import restore_checkpoint, save_checkpoint
from repro.data import (SyntheticLM, batches, make_dataset_family,
                        mixed_request_batch)
from repro.optim import (adamw_init, adamw_update, clip_by_global_norm,
                         cosine_schedule)


def test_synthetic_lm_deterministic_and_dataset_specific():
    a1 = SyntheticLM(128, name="gpqa").sample(
        np.random.default_rng(0), 4, 64)
    a2 = SyntheticLM(128, name="gpqa").sample(
        np.random.default_rng(0), 4, 64)
    b = SyntheticLM(128, name="aime").sample(
        np.random.default_rng(0), 4, 64)
    assert (a1 == a2).all()
    assert not (a1 == b).all()
    assert a1.min() >= 0 and a1.max() < 128


def test_markov_structure_is_learnable_signal():
    """Bigram predictability of one dataset's chain >> random chance."""
    lm = SyntheticLM(64, name="x", branch=4)
    seq = lm.sample(np.random.default_rng(1), 1, 4000)[0]
    # empirical bigram table
    counts = np.zeros((64, 64))
    for a, b in zip(seq[:-1], seq[1:]):
        counts[a, b] += 1
    pred = counts.argmax(1)
    acc = (pred[seq[:-1]] == seq[1:]).mean()
    assert acc > 0.3   # >> 1/64 chance


def test_batches_audio_codebooks():
    lm = SyntheticLM(32, name="music")
    b = next(batches(lm, batch=2, seq_len=8, num_codebooks=4))
    assert b.shape == (2, 8, 4)


def test_mixed_request_batch_uses_all_datasets():
    fam = make_dataset_family(64, ["a", "b", "c", "d"])
    mb = mixed_request_batch(fam, seq_len=16)
    assert mb.shape == (4, 16)


def test_adamw_converges_quadratic():
    p = {"w": jnp.ones((8,)) * 5.0}
    st = adamw_init(p)
    for _ in range(300):
        g = jax.grad(lambda pp: jnp.sum(pp["w"] ** 2))(p)
        p, st = adamw_update(g, st, p, lr=0.05, weight_decay=0.0)
    assert float(jnp.abs(p["w"]).max()) < 1e-2
    assert int(st.step) == 300


def test_cosine_schedule_shape():
    s = cosine_schedule(1.0, 10, 100)
    assert float(s(jnp.asarray(0))) == 0.0
    assert abs(float(s(jnp.asarray(10))) - 1.0) < 1e-6
    assert float(s(jnp.asarray(100))) < 1e-3
    assert float(s(jnp.asarray(55))) < float(s(jnp.asarray(20)))


def test_clip_by_global_norm():
    g = {"a": jnp.ones((4,)) * 10.0}
    clipped, gn = clip_by_global_norm(g, 1.0)
    assert abs(float(gn) - 20.0) < 1e-4
    norm_after = float(jnp.sqrt(jnp.sum(clipped["a"] ** 2)))
    assert abs(norm_after - 1.0) < 1e-4


def test_checkpoint_roundtrip_mixed_dtypes():
    tree = {"a": jnp.arange(6, dtype=jnp.int32).reshape(2, 3),
            "b": {"c": (jnp.ones(4, jnp.bfloat16) * 1.5,
                        jnp.linspace(0, 1, 5))}}
    with tempfile.TemporaryDirectory() as d:
        path = os.path.join(d, "ck")
        save_checkpoint(path, tree, step=3, extra={"note": "t"})
        target = jax.tree_util.tree_map(jnp.zeros_like, tree)
        back = restore_checkpoint(path, target)
    assert (np.asarray(back["a"]) == np.asarray(tree["a"])).all()
    assert back["b"]["c"][0].dtype == jnp.bfloat16
    np.testing.assert_allclose(np.asarray(back["b"]["c"][1]),
                               np.linspace(0, 1, 5), atol=1e-6)


def test_checkpoint_model_params_roundtrip():
    from repro.configs.registry import ARCHS
    from repro.models import init_params
    cfg = ARCHS["granite-moe-1b-a400m"].reduced()
    p = init_params(cfg, jax.random.PRNGKey(0))
    with tempfile.TemporaryDirectory() as d:
        path = os.path.join(d, "m")
        save_checkpoint(path, p, step=1)
        back = restore_checkpoint(
            path, jax.tree_util.tree_map(jnp.zeros_like, p))
    for a, b in zip(jax.tree_util.tree_leaves(p),
                    jax.tree_util.tree_leaves(back)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32), atol=1e-6)
