"""Small-mesh dry-run integration: the full partition-rule + lowering
pipeline on an 8-host-device (2x4) mesh, run in a SUBPROCESS so the
forced device count never leaks into other tests."""
import json
import subprocess
import sys
import textwrap

import pytest

SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import json
    import dataclasses
    import jax, jax.numpy as jnp
    from repro.configs.registry import get_config
    from repro.configs.base import ShapeConfig
    from repro.launch.dryrun import lower_one
    from repro.launch.mesh import make_mesh_compat
    mesh = make_mesh_compat((2, 4), ("data", "model"))
    results = {}
    for arch, fam in [("granite-moe-1b-a400m", "moe"),
                      ("mamba2-370m", "ssm"),
                      ("zamba2-1.2b", "hybrid"),
                      ("musicgen-large", "audio")]:
        cfg = get_config(arch).reduced(num_layers=2, max_d_model=256)
        # tiny shapes, mesh-divisible
        train = ShapeConfig(name="train_4k", seq_len=64, global_batch=4,
                            kind="train")
        decode = ShapeConfig(name="decode_32k", seq_len=64, global_batch=4,
                             kind="decode", cache_len=64)
        for shape in (train, decode):
            rec = lower_one(cfg, shape, mesh)
            results[f"{arch}:{shape.kind}"] = dict(
                flops=rec["flops_per_device"],
                coll=rec["collective_bytes_per_device"],
                dom=rec["dominant"])
    print("RESULT " + json.dumps(results))
""")


@pytest.mark.slow
def test_small_mesh_dryrun_all_families():
    out = subprocess.run(
        [sys.executable, "-c", SCRIPT], capture_output=True, text=True,
        timeout=1200, env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin",
                           "HOME": "/root"})
    assert out.returncode == 0, out.stderr[-3000:]
    line = [ln for ln in out.stdout.splitlines()
            if ln.startswith("RESULT ")][0]
    results = json.loads(line[len("RESULT "):])
    assert len(results) == 8
    for k, v in results.items():
        assert v["flops"] > 0, k
        # every distributed combo must actually communicate
        assert v["coll"] > 0, k
