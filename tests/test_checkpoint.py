"""Round-trip fidelity for checkpoint/ckpt.py.

The serving crash-tolerance layer (serving/journal.py snapshots) now
depends on checkpoints restoring EXACTLY what was saved — shape, value,
and dtype — across jax and host-numpy trees, bfloat16 included.
"""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import (load_checkpoint, restore_checkpoint,
                              save_checkpoint)


def tree_paths(tree):
    return jax.tree_util.tree_flatten_with_path(tree)[0]


@pytest.fixture
def nested_tree():
    return {
        "w_bf16": jnp.arange(6, dtype=jnp.bfloat16).reshape(2, 3) / 3,
        "b_f32": jnp.linspace(0, 1, 5, dtype=jnp.float32),
        "layers": {
            "i32": jnp.arange(4, dtype=jnp.int32),
            "f16": jnp.full((2, 2), 0.5, jnp.float16),
            "stack": [jnp.ones((2, 2)), jnp.zeros((3,))],
        },
        "host": {
            "i64": np.arange(3, dtype=np.int64) * 2**40,
            "f64": np.array([1e-12, np.pi], np.float64),
            "mask": np.array([True, False, True]),
            "empty": np.zeros((0,), np.int32),
        },
    }


def test_round_trip_values_shapes_dtypes(tmp_path, nested_tree):
    path = os.path.join(tmp_path, "ck")
    save_checkpoint(path, nested_tree, step=3, extra={"tag": "t"})
    restored = restore_checkpoint(path, nested_tree)
    for (pa, a), (pb, b) in zip(tree_paths(nested_tree),
                                tree_paths(restored)):
        assert pa == pb
        assert np.shape(a) == np.shape(b), pa
        assert np.asarray(a).dtype == np.asarray(b).dtype, pa
        # compare in f32 so bf16 comparisons are exact-by-cast
        np.testing.assert_array_equal(
            np.asarray(jnp.asarray(a, jnp.float32)),
            np.asarray(jnp.asarray(b, jnp.float32)), err_msg=str(pa))


def test_bfloat16_exact_bits(tmp_path):
    """bf16 is stored as its f32 upcast (npz has no bf16) and must come
    back bit-exact: f32 holds every bf16 value exactly."""
    x = {"p": jnp.asarray(
        np.random.default_rng(0).standard_normal((16, 8)), jnp.bfloat16)}
    path = os.path.join(tmp_path, "bf16")
    save_checkpoint(path, x)
    r = restore_checkpoint(path, x)
    assert r["p"].dtype == jnp.bfloat16
    np.testing.assert_array_equal(
        np.asarray(x["p"]).view(np.uint16),
        np.asarray(r["p"]).view(np.uint16))


def test_host_numpy_64bit_dtypes_survive(tmp_path):
    """Host numpy trees (serving snapshots, optimizer counters) must NOT
    be clamped to 32-bit by the x64-disabled jax default."""
    tree = {"slots": np.array([2**40, -1, 7], np.int64),
            "t": np.array([1.5e300], np.float64)}
    path = os.path.join(tmp_path, "host")
    save_checkpoint(path, tree)
    r = restore_checkpoint(path, tree)
    assert r["slots"].dtype == np.int64
    assert r["t"].dtype == np.float64
    np.testing.assert_array_equal(r["slots"], tree["slots"])
    np.testing.assert_array_equal(r["t"], tree["t"])


def test_load_checkpoint_flat(tmp_path, nested_tree):
    """Target-free loading (the snapshot layer's entry point): flat
    path-keyed arrays + the JSON sidecar."""
    path = os.path.join(tmp_path, "flat")
    save_checkpoint(path, nested_tree, step=9, extra={"seq": 4})
    flat, meta = load_checkpoint(path)
    assert meta["step"] == 9 and meta["extra"]["seq"] == 4
    assert set(meta["keys"]) == set(flat)
    np.testing.assert_array_equal(
        flat["layers/i32"], np.asarray(nested_tree["layers"]["i32"]))
    np.testing.assert_array_equal(
        flat["host/i64"], nested_tree["host"]["i64"])
    # sidecar dtype record distinguishes the bf16 upcast
    assert meta["dtypes"]["w_bf16"] == "float32"
    assert meta["dtypes"]["host/i64"] == "int64"


def test_restore_shape_mismatch_fails_loudly(tmp_path, nested_tree):
    path = os.path.join(tmp_path, "mismatch")
    save_checkpoint(path, nested_tree)
    bad = jax.tree_util.tree_map(lambda x: x, nested_tree)
    bad["b_f32"] = jnp.zeros((7,), jnp.float32)
    with pytest.raises(AssertionError):
        restore_checkpoint(path, bad)
