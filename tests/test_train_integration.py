"""End-to-end training integration: the tiny-MoE LM must actually learn
the synthetic Markov structure (loss drops), with and without XShare
routing active at train time, and a checkpoint restores to the same loss."""
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import (ArchConfig, AttnConfig, MoEConfig,
                                XSharePolicy)
from repro.data import SyntheticLM, batches
from repro.launch.train import make_train_step
from repro.models import init_params, loss_fn
from repro.optim import adamw_init, cosine_schedule

TINY_MOE = ArchConfig(
    name="tiny-moe", family="moe", num_layers=2, d_model=64, d_ff=0,
    vocab_size=128,
    attn=AttnConfig(num_heads=4, num_kv_heads=2, head_dim=16),
    moe=MoEConfig(num_experts=4, top_k=2, d_ff_expert=64),
)


def run_training(steps=40, policy=XSharePolicy(mode="off")):
    params = init_params(TINY_MOE, jax.random.PRNGKey(0))
    opt = adamw_init(params)
    step_fn = jax.jit(make_train_step(
        TINY_MOE, policy=policy, lr=cosine_schedule(3e-3, 5, steps),
        remat=False, capacity_factor=4.0))
    lm = SyntheticLM(TINY_MOE.vocab_size, name="train-test", branch=4)
    stream = batches(lm, batch=8, seq_len=64, seed=0)
    losses = []
    for _ in range(steps):
        params, opt, m = step_fn(params, opt, jnp.asarray(next(stream)))
        losses.append(float(m["loss"]))
    return params, losses


def test_training_reduces_loss():
    params, losses = run_training()
    assert all(np.isfinite(losses))
    assert losses[-1] < losses[0] - 0.5, (losses[0], losses[-1])


def test_training_with_xshare_policy_stays_stable():
    _, losses = run_training(
        steps=20, policy=XSharePolicy(mode="batch", k0=1, m_l=2))
    assert all(np.isfinite(losses))
    assert losses[-1] < losses[0]


def test_checkpoint_restores_training_state():
    from repro.checkpoint import restore_checkpoint, save_checkpoint
    params, _ = run_training(steps=10)
    lm = SyntheticLM(TINY_MOE.vocab_size, name="train-test", branch=4)
    toks = jnp.asarray(next(batches(lm, batch=8, seq_len=64, seed=1)))
    ref_loss = float(loss_fn(TINY_MOE, params, toks, remat=False)[0])
    with tempfile.TemporaryDirectory() as d:
        path = os.path.join(d, "ck")
        save_checkpoint(path, params, step=10)
        back = restore_checkpoint(
            path, jax.tree_util.tree_map(jnp.zeros_like, params))
    got = float(loss_fn(TINY_MOE, back, toks, remat=False)[0])
    assert abs(got - ref_loss) < 1e-5


def test_remat_matches_no_remat_loss():
    params = init_params(TINY_MOE, jax.random.PRNGKey(0))
    lm = SyntheticLM(TINY_MOE.vocab_size, name="x", branch=4)
    toks = jnp.asarray(next(batches(lm, batch=4, seq_len=32, seed=0)))
    l1 = float(loss_fn(TINY_MOE, params, toks, remat=False)[0])
    l2 = float(loss_fn(TINY_MOE, params, toks, remat=True)[0])
    assert abs(l1 - l2) < 1e-5
    g1 = jax.grad(lambda p: loss_fn(TINY_MOE, p, toks, remat=False)[0])(
        params)
    g2 = jax.grad(lambda p: loss_fn(TINY_MOE, p, toks, remat=True)[0])(
        params)
    for a, b in zip(jax.tree_util.tree_leaves(g1),
                    jax.tree_util.tree_leaves(g2)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-4)
