"""Crash-tolerant front door: streaming, cancellation, drain,
error-taxonomy surfacing, and kill-and-recover replay.

The ISSUE-8 acceptance criteria live here:
  * the kill-and-recover path loses ZERO admitted requests;
  * greedy streams are bit-identical to an uninterrupted run
    (replay fidelity 1.0);
  * recovery works from snapshot + journal tail, from the journal
    alone (crash_before_snapshot), and across a torn journal tail.
"""
import os

import jax
import numpy as np
import pytest

from repro.configs.registry import ARCHS
from repro.models import init_params
from repro.serving import (DeadlineExceeded, Engine, Fault, FaultInjector,
                           FrontDoor, InvalidRequest, JournalWriter,
                           QueueFull, RequestCancelled, ShuttingDown,
                           SimulatedCrash, read_journal, recover)
from repro.serving.errors import (REASON_CANCELLED, REASON_COMPLETED,
                                  REASON_WALL)


def small(name, **kw):
    return ARCHS[name].reduced(num_layers=2, max_d_model=128,
                               max_vocab=256, **kw)


@pytest.fixture(scope="module")
def moe_setup():
    cfg = small("granite-moe-1b-a400m")
    params = init_params(cfg, jax.random.PRNGKey(1))
    prompts = np.asarray(jax.random.randint(
        jax.random.PRNGKey(2), (3, 12), 0, cfg.vocab_size))
    return cfg, params, prompts


@pytest.fixture(scope="module")
def engine(moe_setup):
    cfg, params, _ = moe_setup
    return Engine(cfg, params, cache_len=128, decode_chunk=4)


@pytest.fixture(scope="module")
def spec_engine(moe_setup):
    """Self-draft speculative engine: every round journals a multi-token
    burst (full acceptance), the hardest case for burst durability."""
    cfg, params, _ = moe_setup
    return Engine(cfg, params, cache_len=128, draft=(cfg, params),
                  spec_len=3)


def stream_tokens(stream):
    return np.asarray([int(t) for t in stream.tokens])


# ----------------------------------------------------------- streaming ----

def test_streaming_token_exact(engine, moe_setup):
    """Tokens consumed live off the streams are bit-identical to the
    batch generate() reference, and drain() leaves everything terminal."""
    _, _, prompts = moe_setup
    free, _ = engine.generate(prompts, 12)
    door = engine.make_frontdoor(num_slots=2)
    streams = [door.submit(prompts[b], 12) for b in range(3)]
    live = list(streams[0])                 # consume one stream as it runs
    assert len(live) == 12
    out = door.drain(timeout=120.0)
    assert out == streams and door.crashed is None
    for b, s in enumerate(streams):
        assert s.finish_reason == REASON_COMPLETED
        np.testing.assert_array_equal(stream_tokens(s), free[b])
        np.testing.assert_array_equal(s.result(timeout=1.0).ravel(),
                                      free[b])


def test_submit_validation_is_synchronous(engine, moe_setup):
    _, _, prompts = moe_setup
    door = engine.make_frontdoor(num_slots=1)
    with pytest.raises(InvalidRequest):
        door.submit(prompts[0], 0)
    with pytest.raises(InvalidRequest):
        door.submit(prompts[0], 500)        # exceeds cache_len
    assert not door.streams                 # nothing recorded or journaled
    door.drain(timeout=60.0)


# ---------------------------------------------------------- cancellation --

def test_mid_stream_cancel(engine, moe_setup):
    """Cancel after consuming a couple of live tokens: the stream ends
    with the cancelled reason, keeps an exact partial prefix, and
    result() raises RequestCancelled."""
    _, _, prompts = moe_setup
    free, _ = engine.generate(prompts[:1], 48)
    door = engine.make_frontdoor(num_slots=1)
    stream = door.submit(prompts[0], 48)
    it = iter(stream)
    got = [next(it), next(it)]
    assert door.cancel(stream.rid)
    rest = list(it)                         # drains to the end marker
    door.drain(timeout=120.0)
    assert stream.finish_reason == REASON_CANCELLED
    n = len(got) + len(rest)
    assert 2 <= n < 48
    np.testing.assert_array_equal(stream_tokens(stream), free[0][:n])
    with pytest.raises(RequestCancelled):
        stream.result(timeout=1.0)
    assert not door.cancel(stream.rid)      # already terminal
    assert not door.cancel(999)             # unknown rid


# ------------------------------------------------------------- drain ------

def test_drain_closes_admissions(engine, moe_setup):
    _, _, prompts = moe_setup
    door = engine.make_frontdoor(num_slots=1)
    door.submit(prompts[0], 4)
    door.drain(timeout=120.0)
    with pytest.raises(ShuttingDown):
        door.submit(prompts[1], 4)
    # drain is idempotent
    assert len(door.drain(timeout=1.0)) == 1


def test_wall_timeout_leaves_no_live_streams(engine, moe_setup):
    """run(max_wall_s=...) expiry is the one way the serve loop exits
    with work pending: every stream must still reach a terminal state
    (never hang a consumer blocked in result()) and the door must be
    closed to further admissions."""
    _, _, prompts = moe_setup
    door = FrontDoor(engine, num_slots=1, max_wall_s=0.05)
    stream = door.submit(prompts[0], 100)   # inboxed before the loop runs
    door.start()
    door._thread.join(timeout=120.0)
    assert not door._thread.is_alive()
    assert stream.done                      # terminal, not abandoned
    assert stream.finish_reason in (REASON_WALL, REASON_COMPLETED)
    with pytest.raises(ShuttingDown):
        door.submit(prompts[1], 4)
    out = door.drain(timeout=10.0)
    assert all(s.done for s in out)


# ----------------------------------------------------- taxonomy surface ---

def test_overload_reject_surfaces_queue_full(engine, moe_setup):
    """overload='reject' refusals surface as QueueFull from result().
    The door is started only after all submits are inboxed, so the
    admission order (hog -> queue, rest -> refused) is deterministic."""
    _, _, prompts = moe_setup
    door = FrontDoor(engine, num_slots=1, max_queue=1, overload="reject")
    hog = door.submit(prompts[0], 16)
    r1 = door.submit(prompts[1], 8)
    r2 = door.submit(prompts[2], 8)
    door.start()
    door.drain(timeout=120.0)
    assert hog.finish_reason == REASON_COMPLETED
    for r in (r1, r2):
        assert r.finish_reason == "shed_queue"
        with pytest.raises(QueueFull):
            r.result(timeout=1.0)


def test_ttft_deadline_surfaces_deadline_exceeded(engine, moe_setup):
    _, _, prompts = moe_setup
    door = engine.make_frontdoor(num_slots=1)
    hog = door.submit(prompts[0], 48)
    late = door.submit(prompts[1], 8, ttft_deadline_s=1e-4)
    door.drain(timeout=120.0)
    assert hog.finish_reason == REASON_COMPLETED
    assert late.finish_reason == "deadline_ttft"
    with pytest.raises(DeadlineExceeded):
        late.result(timeout=1.0)


# ------------------------------------------------------ kill + recover ----

def test_kill_and_recover_bit_identical(engine, moe_setup, tmp_path):
    """The tentpole guarantee: crash mid-round with a torn journal
    write, recover from snapshot + journal tail, and every admitted
    request finishes with a stream bit-identical to the uninterrupted
    run — zero lost requests, replay fidelity 1.0."""
    _, _, prompts = moe_setup
    free, _ = engine.generate(prompts, 12)
    jp = os.path.join(tmp_path, "wal.journal")
    sp = os.path.join(tmp_path, "snap")
    inj = FaultInjector([Fault("crash_mid_round", step=2),
                         Fault("journal_torn_write", nbytes=7)])
    door = FrontDoor(engine, num_slots=2, journal_path=jp,
                     snapshot_path=sp, snapshot_every_rounds=1,
                     faults=inj).start()
    streams = [door.submit(prompts[b], 12) for b in range(3)]
    door.drain(timeout=120.0)
    assert isinstance(door.crashed, SimulatedCrash)
    assert door.snapshots_written >= 1
    # crash aborts, never silently hangs: every stream is terminal
    for s in streams:
        assert s.done
        if s.finish_reason is None:
            assert s.error is door.crashed

    door2, report = recover(engine, journal_path=jp, snapshot_path=sp,
                            num_slots=2)
    # zero lost admitted requests
    assert report.requests == 3
    assert report.resumed + report.terminal == 3
    assert report.snapshot_used
    door2.drain(timeout=120.0)
    assert door2.crashed is None
    for b in range(3):
        s = door2.streams[b]
        assert s.finish_reason == REASON_COMPLETED
        np.testing.assert_array_equal(stream_tokens(s), free[b])
    stats = door2.replay_stats()
    assert stats["mismatches"] == 0 and stats["fidelity"] == 1.0
    # the journal is whole again: recovery truncated the torn fragment
    # and the new incarnation's records (finishes) are all readable
    tail = read_journal(jp)
    assert not tail.torn
    finished = {r["rid"] for r in tail.records if r["t"] == "finish"}
    assert finished == {0, 1, 2}


def test_spec_streaming_bursts_token_exact(engine, spec_engine, moe_setup,
                                           tmp_path):
    """Speculative requests stream through the front door in multi-token
    bursts: journaled token records really carry bursts (len > 1), the
    submit records carry the spec flag, mixed spec+plain traffic shares
    the batch, and every stream equals plain greedy decoding."""
    _, _, prompts = moe_setup
    free, _ = engine.generate(prompts, 12)
    jp = os.path.join(tmp_path, "wal.journal")
    door = FrontDoor(spec_engine, num_slots=2, journal_path=jp).start()
    streams = [door.submit(prompts[b], 12, spec=(b != 1))
               for b in range(3)]
    door.drain(timeout=120.0)
    for b, s in enumerate(streams):
        assert s.finish_reason == REASON_COMPLETED
        assert s.spec == (b != 1)
        np.testing.assert_array_equal(stream_tokens(s), free[b])
    tail = read_journal(jp)
    subs = {r["rid"]: r for r in tail.records if r["t"] == "submit"}
    assert [subs[b]["spec"] for b in range(3)] == [True, False, True]
    bursts = [len(r["tok"]) for r in tail.records if r["t"] == "token"]
    assert max(bursts) > 1                  # real multi-token records


def test_spec_flag_rejected_without_spec_scheduler(engine, moe_setup):
    """spec=True on a plain engine is a synchronous caller error —
    nothing journaled, no stream created."""
    _, _, prompts = moe_setup
    door = engine.make_frontdoor(num_slots=1)
    with pytest.raises(ValueError):
        door.submit(prompts[0], 8, spec=True)
    assert not door.streams
    door.drain(timeout=60.0)


def test_spec_kill_and_recover_bit_identical(engine, spec_engine,
                                             moe_setup, tmp_path):
    """The PR-9 acceptance criterion: crash mid-burst with a torn
    journal write while speculative requests stream, recover, and every
    stream is bit-identical to plain greedy — the journaled spec flag
    survives the snapshot/journal round-trip so replay re-runs
    speculatively."""
    _, _, prompts = moe_setup
    free, _ = engine.generate(prompts, 12)
    jp = os.path.join(tmp_path, "wal.journal")
    sp = os.path.join(tmp_path, "snap")
    inj = FaultInjector([Fault("crash_mid_round", step=2),
                         Fault("journal_torn_write", nbytes=7)])
    door = FrontDoor(spec_engine, num_slots=2, journal_path=jp,
                     snapshot_path=sp, snapshot_every_rounds=1,
                     faults=inj).start()
    streams = [door.submit(prompts[b], 12, spec=(b != 1))
               for b in range(3)]
    door.drain(timeout=120.0)
    assert isinstance(door.crashed, SimulatedCrash)
    for s in streams:
        assert s.done

    door2, report = recover(spec_engine, journal_path=jp,
                            snapshot_path=sp, num_slots=2)
    assert report.requests == 3
    assert report.resumed + report.terminal == 3
    door2.drain(timeout=120.0)
    assert door2.crashed is None
    for b in range(3):
        s = door2.streams[b]
        assert s.spec == (b != 1)           # flag survived the crash
        assert s.finish_reason == REASON_COMPLETED
        np.testing.assert_array_equal(stream_tokens(s), free[b])
    stats = door2.replay_stats()
    assert stats["mismatches"] == 0 and stats["fidelity"] == 1.0


def test_spec_recover_degrades_on_plain_engine(engine, spec_engine,
                                               moe_setup, tmp_path):
    """Recovering a journal full of spec requests on an engine WITHOUT
    a draft model must degrade them to plain decode (greedy speculation
    is lossless, so streams stay bit-identical) instead of crashing the
    serve thread."""
    _, _, prompts = moe_setup
    free, _ = engine.generate(prompts[:2], 24)
    jp = os.path.join(tmp_path, "wal.journal")
    # one fused spec call covers num_rounds=4 draft-verify rounds, so
    # the horizon must outlast round 0 for the crash to fire entering
    # fused round 1
    inj = FaultInjector([Fault("crash_mid_round", step=1)])
    door = FrontDoor(spec_engine, num_slots=2, journal_path=jp,
                     fsync_every=1, faults=inj).start()
    for b in range(2):
        door.submit(prompts[b], 24)         # SpecScheduler default: spec
    door.drain(timeout=120.0)
    assert isinstance(door.crashed, SimulatedCrash)

    door2, report = recover(engine, journal_path=jp, num_slots=2)
    assert report.resumed == 2
    door2.drain(timeout=120.0)
    for b in range(2):
        s = door2.streams[b]
        assert not s.spec                   # degraded to plain decode
        assert s.finish_reason == REASON_COMPLETED
        np.testing.assert_array_equal(stream_tokens(s), free[b][:24])


def test_crash_before_snapshot_recovers_from_journal_alone(
        engine, moe_setup, tmp_path):
    """The crash lands BEFORE the first snapshot is written: recovery
    has only the journal — still zero lost requests, still exact."""
    _, _, prompts = moe_setup
    free, _ = engine.generate(prompts[:2], 10)
    jp = os.path.join(tmp_path, "wal.journal")
    sp = os.path.join(tmp_path, "snap")
    inj = FaultInjector([Fault("crash_before_snapshot", step=0)])
    door = FrontDoor(engine, num_slots=2, journal_path=jp,
                     snapshot_path=sp, snapshot_every_rounds=1,
                     fsync_every=1, faults=inj).start()
    for b in range(2):
        door.submit(prompts[b], 10)
    door.drain(timeout=120.0)
    assert isinstance(door.crashed, SimulatedCrash)
    assert door.snapshots_written == 0
    assert not os.path.exists(sp + ".npz")

    door2, report = recover(engine, journal_path=jp, snapshot_path=sp,
                            num_slots=2)
    assert not report.snapshot_used and report.requests == 2
    door2.drain(timeout=120.0)
    for b in range(2):
        s = door2.streams[b]
        assert s.finish_reason == REASON_COMPLETED
        np.testing.assert_array_equal(stream_tokens(s), free[b])
    assert door2.replay_stats()["mismatches"] == 0


def test_recover_flags_mid_file_token_gap(engine, moe_setup, tmp_path):
    """A token record starting beyond the accumulated tokens is mid-file
    corruption: recovery resumes from the consistent prefix but reports
    the rid in corrupt_gaps instead of silently trusting a short
    journal."""
    _, _, prompts = moe_setup
    jp = os.path.join(tmp_path, "wal.journal")
    w = JournalWriter(jp)
    w.append("submit", rid=0, prompt=prompts[0].tolist(), max_new=10,
             arrival_s=0.0)
    w.append("token", rid=0, i=0, tok=[1, 2])
    w.append("token", rid=0, i=5, tok=[3])  # gap: records lost mid-file
    w.close()
    door2, report = recover(engine, journal_path=jp, num_slots=1)
    assert report.corrupt_gaps == 1
    assert report.resumed == 1
    s = door2.streams[0]
    assert s.replayed == 2                  # consistent prefix only
    door2.drain(timeout=120.0)
    assert s.finish_reason == REASON_COMPLETED


def test_torn_tail_recovery_no_snapshot(engine, moe_setup, tmp_path):
    """Large fsync batch + no snapshots: the crash loses every buffered
    token record and tears the next one. Recovery sees the torn tail,
    truncates it, and regenerates the full streams from the durable
    submit records alone."""
    _, _, prompts = moe_setup
    free, _ = engine.generate(prompts[:2], 10)
    jp = os.path.join(tmp_path, "wal.journal")
    inj = FaultInjector([Fault("crash_mid_round", step=1),
                         Fault("journal_torn_write", nbytes=6)])
    door = FrontDoor(engine, num_slots=2, journal_path=jp,
                     fsync_every=64, faults=inj).start()
    for b in range(2):
        door.submit(prompts[b], 10)
    door.drain(timeout=120.0)
    assert isinstance(door.crashed, SimulatedCrash)
    pre = read_journal(jp)
    assert pre.torn                         # fragment really on disk
    assert {r["t"] for r in pre.records} == {"submit"}

    door2, report = recover(engine, journal_path=jp, num_slots=2)
    assert report.torn_tail and not report.snapshot_used
    assert report.resumed == 2
    door2.drain(timeout=120.0)
    for b in range(2):
        s = door2.streams[b]
        assert s.finish_reason == REASON_COMPLETED
        assert s.replayed == 0              # nothing durable to replay
        np.testing.assert_array_equal(stream_tokens(s), free[b])
    assert not read_journal(jp).torn
