"""MoE layer: dispatch/combine correctness, grouped-dispatch equivalence,
XShare policy integration, capacity-drop semantics, kernel-path parity."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import MoEConfig, XSharePolicy
from repro.kernels.ref import moe_ffn_ref
from repro.models.moe import OFF, expert_ffn, init_moe, moe_apply, route

MOE = MoEConfig(num_experts=8, top_k=2, d_ff_expert=32)
D = 16


def setup(T=12, seed=0):
    p = init_moe(jax.random.PRNGKey(seed), MOE, D, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(seed + 1), (T, D))
    return p, x


@pytest.mark.parametrize("dispatch", ["sorted", "einsum", "dense", "auto"])
def test_expert_ffn_matches_dense_reference(dispatch):
    """Drop-free capacity == the dense masked-expert oracle, on every
    dispatch path."""
    p, x = setup()
    idx, w, combine, _ = route(p, x, MOE, OFF)
    y = expert_ffn(p, x, idx, w, MOE, capacity=x.shape[0],
                   dispatch=dispatch, combine=combine)
    ref = moe_ffn_ref(x, p["w1"], p["w3"], p["w2"], combine,
                      jnp.ones(MOE.num_experts, bool))
    np.testing.assert_allclose(np.asarray(y), np.asarray(ref), atol=1e-4)


def test_grouped_dispatch_matches_single_group():
    p, x = setup(T=64)
    idx, w, _, _ = route(p, x, MOE, OFF)
    y1 = expert_ffn(p, x, idx, w, MOE, capacity=64, group_size=10**9,
                    dispatch="einsum")
    # grouped path with per-group drop-free capacity
    y2 = expert_ffn(p, x, idx, w, MOE, capacity=16, group_size=16,
                    dispatch="einsum")
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=1e-4)


def test_capacity_drops_zero_out_overflow_tokens():
    """With capacity 1, an expert routed by many tokens serves only the
    first; the rest lose that expert's contribution (never NaN)."""
    p, x = setup(T=6)
    idx = jnp.zeros((6, 2), jnp.int32).at[:, 1].set(1)  # all -> experts 0,1
    w = jnp.full((6, 2), 0.5)
    y = expert_ffn(p, x, idx, w, MOE, capacity=1, dispatch="einsum")
    assert bool(jnp.isfinite(y).all())
    full = expert_ffn(p, x, idx, w, MOE, capacity=6, dispatch="einsum")
    assert float(jnp.abs(y[0] - full[0]).max()) < 1e-5   # first token kept
    assert float(jnp.abs(y[1]).max()) == 0.0             # dropped entirely


@pytest.mark.parametrize("mode,kwargs", [
    ("batch", dict(k0=1, m_l=2)),
    ("ep", dict(k0=1, m_g=1, num_groups=4)),
])
def test_policy_reduces_activation(mode, kwargs):
    p, x = setup(T=32)
    _, _, _, aux_off = route(p, x, MOE, OFF)
    pol = XSharePolicy(mode=mode, **kwargs)
    _, _, _, aux_on = route(p, x, MOE, pol)
    assert int(aux_on["activated_experts"]) <= int(
        aux_off["activated_experts"])
    assert float(aux_on["gate_mass"]) <= 1.0
    if mode == "ep":
        assert int(aux_on["max_group_load"]) <= 1


def test_spec_policy_through_layer():
    p, x = setup(T=12)
    pol = XSharePolicy(mode="spec", k0=1, m_l=0, m_r=2)
    y, aux = moe_apply(p, x.reshape(3, 4, D), MOE, pol, spec_shape=(3, 4),
                       capacity=12)
    assert y.shape == (3, 4, D)
    assert bool(jnp.isfinite(y).all())
    assert int(aux["selected_set"]) <= MOE.num_experts


def test_moe_apply_with_shared_experts():
    moe = MoEConfig(num_experts=4, top_k=2, d_ff_expert=16,
                    num_shared_experts=1, d_ff_shared=16)
    p = init_moe(jax.random.PRNGKey(0), moe, D, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 5, D))
    y, _ = moe_apply(p, x, moe, OFF, capacity=10)
    assert y.shape == x.shape
    # shared experts contribute even when routed gates are zeroed
    p0 = dict(p)
    y_shared_only, _ = moe_apply(
        {**p, "wg": jnp.full_like(p["wg"], -1e9)}, x, moe, OFF, capacity=10)
    assert bool(jnp.isfinite(y_shared_only).all())


def test_layer_output_matches_pallas_kernel_path():
    """einsum dispatch path == Pallas masked-FFN kernel on the same
    routing decisions (serving hot-loop parity)."""
    from repro.kernels.ops import xshare_moe_ffn
    p, x = setup(T=8)
    pol = XSharePolicy(mode="batch", k0=1, m_l=2)
    idx, w, combine, aux = route(p, x, MOE, pol)
    active = (combine > 0).any(0)
    y_einsum = expert_ffn(p, x, idx, w, MOE, capacity=8,
                          dispatch="einsum")
    y_kernel = xshare_moe_ffn(x, p["w1"], p["w3"], p["w2"], combine,
                              active, max_active=8, block_f=32)
    np.testing.assert_allclose(np.asarray(y_einsum), np.asarray(y_kernel),
                               atol=1e-4)
