"""Property tests for the XShare selection algorithms (paper Sec 3-5).

The central theoretical claim (Prop 3.2 / Cor 3.3): the per-layer proxy
objective is modular, so greedy == exhaustive optimum. We verify that
literally against brute force on small instances, plus the structural
invariants of every algorithm.
"""
import itertools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:
    # hypothesis isn't a hard dependency: fall back to a deterministic
    # mini-sampler so the property tests still run (with fixed draws)
    # everywhere, and with full random search wherever hypothesis is
    # installed (CI installs it).
    class _Strategy:
        def __init__(self, draw):
            self.draw = draw

    class st:  # noqa: N801 - mirrors the hypothesis module name
        @staticmethod
        def integers(lo, hi):
            return _Strategy(lambda rng: int(rng.integers(lo, hi + 1)))

        @staticmethod
        def sampled_from(xs):
            return _Strategy(lambda rng: xs[int(rng.integers(len(xs)))])

    def settings(**_kw):
        return lambda f: f

    def given(**strategies):
        def deco(f):
            def wrapper():
                rng = np.random.default_rng(0)
                for _ in range(8):
                    f(**{k: s.draw(rng) for k, s in strategies.items()})
            # NB: no functools.wraps — pytest would follow __wrapped__
            # back to f's signature and treat the draws as fixtures
            wrapper.__name__ = f.__name__
            wrapper.__doc__ = f.__doc__
            return wrapper
        return deco

from repro.configs.base import XSharePolicy
from repro.core import (batch_select, ep_select, greedy_select,
                        per_request_select, restricted_topk, spec_select,
                        topk_mask, warmup_union)
from repro.core.metrics import (expected_activated, gate_mass_captured,
                                max_group_load, topk_overlap)
from repro.core.selection import apply_policy


def rand_gates(seed, T, E):
    logits = jax.random.normal(jax.random.PRNGKey(seed), (T, E))
    return np.asarray(jax.nn.softmax(logits, axis=-1))


# ---------------------------------------------------------- optimality ----

@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 10_000), T=st.integers(1, 6),
       E=st.integers(2, 8), m=st.integers(0, 8))
def test_greedy_matches_bruteforce_modular_optimum(seed, T, E, m):
    """Cor 3.3: top-m by aggregated score == exhaustive max of f(S),
    |S| <= m (no warm-up)."""
    g = rand_gates(seed, T, E)
    sel = np.asarray(greedy_select(jnp.asarray(g), m))
    got = g.sum(0)[sel].sum()
    best = 0.0
    mm = min(m, E)
    for combo in itertools.combinations(range(E), mm):
        best = max(best, g.sum(0)[list(combo)].sum())
    assert got >= best - 1e-6
    assert sel.sum() == min(m, E)


@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 10_000), T=st.integers(1, 6),
       E=st.integers(4, 10), m=st.integers(0, 6), k0=st.integers(0, 2))
def test_warmup_always_included_and_budget_respected(seed, T, E, m, k0):
    g = jnp.asarray(rand_gates(seed, T, E))
    s0 = warmup_union(g, k0)
    sel = batch_select(g, m, k0)
    assert bool(jnp.all(sel | ~s0)), "warm-up experts must stay selected"
    assert int(sel.sum()) <= int(s0.sum()) + m
    if m == 0:
        assert bool(jnp.all(sel == s0))


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 10_000), T=st.integers(2, 8),
       E=st.integers(4, 12), m=st.integers(1, 6))
def test_batch_select_token_permutation_invariant(seed, T, E, m):
    g = rand_gates(seed, T, E)
    perm = np.random.default_rng(seed).permutation(T)
    a = np.asarray(batch_select(jnp.asarray(g), m, 1))
    b = np.asarray(batch_select(jnp.asarray(g[perm]), m, 1))
    assert (a == b).all()


# ------------------------------------------------------------------- EP ---

@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 10_000), T=st.integers(1, 6),
       G=st.sampled_from([2, 4]), per=st.sampled_from([2, 4]),
       m_g=st.integers(1, 4), k0=st.integers(0, 2))
def test_ep_select_respects_max_load(seed, T, G, per, m_g, k0):
    """Alg 5/6: MaxLoad(S) <= m_g by construction (strict cap)."""
    E = G * per
    g = jnp.asarray(rand_gates(seed, T, E))
    sel = ep_select(g, m_g, G, k0, strict_cap=True)
    assert int(max_group_load(sel, G)) <= m_g
    # warm-up experts get priority within each group
    s0 = np.asarray(warmup_union(g, k0))
    selected = np.asarray(sel)
    agg = np.asarray(g.sum(0))
    for grp in range(G):
        lo, hi = grp * per, (grp + 1) * per
        w_in = s0[lo:hi]
        if w_in.sum() <= m_g:
            assert (selected[lo:hi] | ~w_in).all()


def test_ep_select_balances_against_plain_greedy():
    """Concentrated scores: plain greedy overloads one group; EP-aware
    selection caps it (the Table 2 mechanism)."""
    E, G, m = 32, 8, 4
    rng = np.random.default_rng(0)
    g = rng.random((16, E)) * 0.01
    g[:, :4] += 10.0                      # all mass on group 0 (4 experts)
    g = jnp.asarray(g / g.sum(-1, keepdims=True))
    plain = greedy_select(g, m)
    ep = ep_select(g, 1, G, 0, strict_cap=True)
    assert int(max_group_load(plain, G)) == 4   # greedy saturates group 0
    assert int(max_group_load(ep, G)) <= 1


# ------------------------------------------------------------ spec mode ---

@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 10_000), b=st.integers(1, 4),
       t=st.integers(1, 4), E=st.integers(4, 10),
       m_r=st.integers(0, 4), m=st.integers(0, 4))
def test_spec_select_contains_per_request_sets(seed, b, t, E, m_r, m):
    logits = jax.random.normal(jax.random.PRNGKey(seed), (b, t, E))
    g = jax.nn.softmax(logits, -1)
    s_r = per_request_select(g, m_r, 1)
    s = spec_select(g, m, m_r, 1)
    assert bool(jnp.all(s | ~s_r.any(0)))
    assert int(s.sum()) <= int(s_r.any(0).sum()) + m


# ----------------------------------------------------------- refinement ---

@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 10_000), T=st.integers(1, 6),
       E=st.integers(4, 12), k=st.integers(1, 4), m=st.integers(1, 8))
def test_refinement_routes_within_selected_set(seed, T, E, k, m):
    g = jnp.asarray(rand_gates(seed, T, E))
    mask = batch_select(g, m, 1)
    idx, w = restricted_topk(g, mask, k)
    sel = np.asarray(mask)
    for tok in range(T):
        for slot in range(min(k, E)):
            if float(w[tok, slot]) > 0:
                assert sel[int(idx[tok, slot])]
    sums = np.asarray(w.sum(-1))
    assert np.all((np.abs(sums - 1.0) < 1e-5) | (sums == 0.0))


def test_apply_policy_off_equals_full_mask():
    g = jnp.asarray(rand_gates(0, 8, 16))
    idx, w, mask = apply_policy(g, XSharePolicy(mode="off"), top_k=4)
    assert int(mask.sum()) == 16
    # off == plain top-k
    ref_idx = jax.lax.top_k(g, 4)[1]
    assert (np.asarray(idx) == np.asarray(ref_idx)).all()


# --------------------------------------------------------------- metrics --

def test_expected_activated_matches_monte_carlo():
    """Fig 1's closed form E[N_a] = N(1-(1-k/N)^B) vs simulation with
    uniform-random independent routing."""
    N, k, B = 64, 4, 16
    rng = np.random.default_rng(0)
    trials = []
    for _ in range(300):
        active = set()
        for _ in range(B):
            active |= set(rng.choice(N, size=k, replace=False))
        trials.append(len(active))
    mc = float(np.mean(trials))
    formula = expected_activated(N, k, B)
    assert abs(mc - formula) / formula < 0.05


def test_gate_mass_and_overlap():
    g = jnp.asarray(rand_gates(3, 4, 8))
    full = gate_mass_captured(g, jnp.ones(8, bool))
    assert abs(float(full) - 1.0) < 1e-6
    half = gate_mass_captured(g, jnp.arange(8) < 4)
    assert 0.0 < float(half) < 1.0
    ov = topk_overlap(jnp.array([[0, 1, 2]]), jnp.array([[1, 2, 3]]), 8)
    assert int(ov[0]) == 2


def test_topk_mask_zero_k():
    assert not bool(topk_mask(jnp.ones((3, 5)), 0).any())


# ---------------------------------------------------- scheduling affinity --

def test_affinity_prefers_overlapping_histogram():
    from repro.core import affinity_score, rank_by_affinity
    E = 8
    running = np.zeros(E)
    running[:4] = 1.0
    same = np.zeros(E)
    same[:4] = 0.25
    other = np.zeros(E)
    other[4:] = 0.25
    scores = np.asarray(rank_by_affinity(jnp.asarray(np.stack([other, same])),
                                         jnp.asarray(running)))
    assert scores[1] > scores[0]
    assert abs(float(affinity_score(jnp.asarray(same),
                                    jnp.asarray(running))) - 1.0) < 1e-6
    # empty running batch: every candidate scores 0 (degenerates to FIFO)
    z = np.asarray(rank_by_affinity(jnp.asarray(np.stack([other, same])),
                                    jnp.zeros(E)))
    assert z.max() == 0.0


def test_warmup_union_ignores_all_zero_rows():
    """Compute-masked tokens (zeroed gate rows) add no warm-up experts."""
    g = np.zeros((3, 6))
    g[0, 2] = 1.0                       # rows 1, 2 are masked out
    s0 = np.asarray(warmup_union(jnp.asarray(g), 1))
    assert s0.sum() == 1 and s0[2]
