"""Speculative decoding edge cases: ragged acceptance at its
boundaries (all accepted / zero accepted / per-row limits / L_s = 1 /
B = 1), spec budgets, and rollback_cur_len interacting with mid-stream
eviction when slots turn over under the SpecScheduler."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import ARCHS
from repro.models import init_params
from repro.serving import (Engine, SpecConfig, greedy_accept,
                           rollback_cur_len)


def small(name, **kw):
    return ARCHS[name].reduced(num_layers=2, max_d_model=128,
                               max_vocab=256, **kw)


@pytest.fixture(scope="module")
def moe_setup():
    cfg = small("granite-moe-1b-a400m")
    params = init_params(cfg, jax.random.PRNGKey(1))
    prompts = np.asarray(jax.random.randint(
        jax.random.PRNGKey(2), (4, 12), 0, cfg.vocab_size))
    return cfg, params, prompts


def _logits(argmaxes, V=16):
    """(1, T, V) logits whose per-position argmax is `argmaxes`."""
    lg = np.full((1, len(argmaxes), V), -10.0, np.float32)
    for i, t in enumerate(argmaxes):
        lg[0, i, t] = 10.0
    return jnp.asarray(lg)


# ------------------------------------------------- greedy_accept units ----

def test_all_accepted_boundary():
    res = greedy_accept(_logits([3, 5, 7, 9]), jnp.array([[3, 5, 7]]))
    assert int(res.accepted[0]) == 3 and int(res.num_new[0]) == 4
    np.testing.assert_array_equal(res.new_tokens[0], [3, 5, 7, 9])


def test_zero_accepted_boundary():
    res = greedy_accept(_logits([4, 5, 7]), jnp.array([[3, 5]]))
    assert int(res.accepted[0]) == 0 and int(res.num_new[0]) == 1
    assert int(res.new_tokens[0, 0]) == 4   # bonus = target's own pick


def test_ls_one_boundary():
    res = greedy_accept(_logits([3, 6]), jnp.array([[3]]))
    assert int(res.accepted[0]) == 1
    np.testing.assert_array_equal(res.new_tokens[0], [3, 6])


def test_limit_zero_degenerates_to_plain_greedy():
    """limit 0 must ignore even perfectly matching drafts — the fused
    heterogeneous batch rides plain rows through the verify pass this
    way."""
    res = greedy_accept(_logits([3, 5, 7, 9]), jnp.array([[3, 5, 7]]),
                        limit=jnp.array([0]))
    assert int(res.accepted[0]) == 0 and int(res.num_new[0]) == 1
    assert int(res.new_tokens[0, 0]) == 3


def test_limit_clamps_matching_prefix():
    res = greedy_accept(_logits([3, 5, 7, 9]), jnp.array([[3, 5, 7]]),
                        limit=jnp.array([2]))
    assert int(res.accepted[0]) == 2
    np.testing.assert_array_equal(res.new_tokens[0, :3], [3, 5, 7])


def test_rollback_cur_len_is_ragged():
    lg = jnp.concatenate([_logits([3, 5, 7, 9]), _logits([4, 5, 7, 9])])
    res = greedy_accept(lg, jnp.array([[3, 5, 7], [3, 5, 7]]))
    cur = rollback_cur_len(jnp.array([10, 20]), res)
    np.testing.assert_array_equal(cur, [14, 21])


# ---------------------------------------------- scheduler-path edges ------

def test_b1_spec_equals_plain(moe_setup):
    cfg, params, prompts = moe_setup
    plain, _ = Engine(cfg, params, cache_len=128).generate(prompts[:1], 16)
    spec, st = Engine(cfg, params, cache_len=128, draft=(cfg, params),
                      spec_len=3).generate(prompts[:1], 16)
    assert np.array_equal(plain, spec)
    assert st.acceptance_rate == 1.0


def test_spec_gate_priors_override(moe_setup):
    """SpecScheduler.gate_priors() serves the EMA verify-pass priors
    through the same stable API the base scheduler exposes — the
    Algorithm-4 correlation priors come from here, not from ad-hoc
    _slot_spec reads."""
    cfg, params, prompts = moe_setup
    eng = Engine(cfg, params, cache_len=128, draft=(cfg, params),
                 spec_len=3)
    E = cfg.moe.num_experts
    captured = []
    sched = eng.make_scheduler(
        num_slots=2, on_round=lambda s, r: captured.append(s.gate_priors()))
    for b in range(2):
        # long enough to span several fused dispatches, so on_round
        # observes slots that are still live with folded-in priors
        sched.submit(prompts[b], 40)
    states = sched.run()
    assert all(s.status == "done" for s in states)
    assert captured and all(c.shape == (2, E) for c in captured)
    # after the first round the verify pass has folded req_gate_hist
    # into every live spec slot's prior
    assert any((c.sum(1) > 0).all() for c in captured)
    for c in captured:
        assert np.isfinite(c).all() and (c >= 0).all()


def test_spec_len_one_equals_plain(moe_setup):
    cfg, params, prompts = moe_setup
    plain, _ = Engine(cfg, params, cache_len=128).generate(prompts, 14)
    spec, _ = Engine(cfg, params, cache_len=128, draft=(cfg, params),
                     spec_len=1).generate(prompts, 14)
    assert np.array_equal(plain, spec)


def test_untrained_draft_still_exact(moe_setup):
    """A draft that almost never agrees with the target (independent
    random init) exercises the zero-accept path round after round —
    output must stay exact and acceptance sane."""
    cfg, params, prompts = moe_setup
    junk = init_params(cfg, jax.random.PRNGKey(77))
    plain, _ = Engine(cfg, params, cache_len=128).generate(prompts, 16)
    spec, st = Engine(cfg, params, cache_len=128, draft=(cfg, junk),
                      spec_len=3).generate(prompts, 16)
    assert np.array_equal(plain, spec)
    assert 0.0 <= st.acceptance_rate <= 1.0
    assert st.drafted > 0


def test_spec_budget_exhaustion_degrades_to_plain(moe_setup):
    """A tiny per-request draft budget runs dry mid-stream: the slot
    must keep decoding plain (lim 0) and stay token-exact, and the
    exhaustion must be counted."""
    cfg, params, prompts = moe_setup
    plain, _ = Engine(cfg, params, cache_len=128).generate(prompts, 16)
    spec, st = Engine(cfg, params, cache_len=128, draft=(cfg, params),
                      spec_len=3, spec_budget=4).generate(prompts, 16)
    assert np.array_equal(plain, spec)
    assert st.spec_budget_exhausted == prompts.shape[0]
    assert 0 < st.accepted <= st.drafted


def test_rollback_with_mid_stream_eviction(moe_setup):
    """More requests than slots with heterogeneous horizons and mixed
    spec/plain flags: slots are evicted and re-admitted mid-run, so the
    per-row draft-cache rollback must survive slot turnover. Invariants
    (target/draft cur_len lockstep per spec slot) are checked every
    round."""
    cfg, params, prompts = moe_setup
    horizons = [10, 17, 5, 12]
    plain, _ = Engine(cfg, params, cache_len=128).generate(
        prompts, max(horizons))
    eng = Engine(cfg, params, cache_len=128, draft=(cfg, params),
                 spec_len=3)
    sched = eng.make_scheduler(num_slots=2, invariants=True)
    sts = [sched.submit(prompts[b], horizons[b], spec=(b != 2))
           for b in range(4)]
    sched.run()
    for b, st in enumerate(sts):
        assert st.finish_reason == "completed"
        np.testing.assert_array_equal(
            np.asarray(st.tokens[:horizons[b]]), plain[b][:horizons[b]])
    assert sts[2].drafted == 0              # plain rider never drafts
    assert sum(s.drafted for s in sts) > 0


def test_adaptive_draft_length_stays_bounded(moe_setup):
    """With a disagreeing draft the per-slot draft length adapts down;
    the invariant check bounds it to [min_draft, spec_len] every
    round."""
    cfg, params, prompts = moe_setup
    junk = init_params(cfg, jax.random.PRNGKey(99))
    eng = Engine(cfg, params, cache_len=128, draft=(cfg, junk),
                 spec_len=4)
    sched = eng.make_scheduler(
        num_slots=2, invariants=True,
        spec_cfg=SpecConfig(spec_len=4, min_draft=1, shrink_below=0.9,
                            grow_above=0.99))
    sts = [sched.submit(prompts[b], 16) for b in range(2)]
    sched.run()
    plain, _ = Engine(cfg, params, cache_len=128).generate(prompts[:2], 16)
    for b, st in enumerate(sts):
        np.testing.assert_array_equal(np.asarray(st.tokens[:16]), plain[b])
