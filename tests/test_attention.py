"""Flash attention (fwd + custom VJP) and cache attention correctness."""
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.attention import (apply_rope, cached_attention,
                                    flash_attention, update_cache)


def naive_attention(q, k, v, window=None):
    B, S, H, dh = q.shape
    Hkv = k.shape[2]
    kf = jnp.repeat(k, H // Hkv, 2)
    vf = jnp.repeat(v, H // Hkv, 2)
    s = jnp.einsum("bqhd,bkhd->bhqk", q, kf) / math.sqrt(dh)
    i = jnp.arange(S)
    mask = i[None, :] <= i[:, None]
    if window:
        mask = mask & (i[None, :] > i[:, None] - window)
    s = jnp.where(mask[None, None], s, -1e30)
    p = jax.nn.softmax(s, -1)
    return jnp.einsum("bhqk,bkhd->bqhd", p, vf)


@pytest.mark.parametrize("S,window,qc,kc", [
    (64, None, 16, 16), (100, None, 32, 16), (64, 24, 16, 16),
    (128, 50, 32, 64),
])
def test_flash_forward_matches_naive(S, window, qc, kc):
    B, H, Hkv, dh = 2, 4, 2, 32
    ks = jax.random.split(jax.random.PRNGKey(S), 3)
    q = jax.random.normal(ks[0], (B, S, H, dh))
    k = jax.random.normal(ks[1], (B, S, Hkv, dh))
    v = jax.random.normal(ks[2], (B, S, Hkv, dh))
    out = flash_attention(q, k, v, window=window, q_chunk=qc, kv_chunk=kc)
    ref = naive_attention(q, k, v, window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5)


@pytest.mark.parametrize("window", [None, 37])
def test_flash_gradients_match_naive(window):
    B, S, H, Hkv, dh = 1, 96, 4, 2, 16
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    q = jax.random.normal(ks[0], (B, S, H, dh))
    k = jax.random.normal(ks[1], (B, S, Hkv, dh))
    v = jax.random.normal(ks[2], (B, S, Hkv, dh))
    tgt = jax.random.normal(jax.random.PRNGKey(9), (B, S, H, dh))

    def lf(fn):
        def inner(q, k, v):
            return jnp.sum((fn(q, k, v) - tgt) ** 2)
        return inner

    g1 = jax.grad(lf(lambda q, k, v: flash_attention(
        q, k, v, window=window, q_chunk=32, kv_chunk=32)),
        argnums=(0, 1, 2))(q, k, v)
    g2 = jax.grad(lf(lambda q, k, v: naive_attention(q, k, v, window)),
                  argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(g1, g2):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=2e-3)


def test_rope_relative_property():
    """RoPE: <q_i, k_j> depends only on i-j."""
    dh = 32
    q = jax.random.normal(jax.random.PRNGKey(0), (1, 1, 1, dh))
    k = jax.random.normal(jax.random.PRNGKey(1), (1, 1, 1, dh))
    def dot_at(pi, pj):
        qr = apply_rope(q, jnp.array([pi]), 10000.0)
        kr = apply_rope(k, jnp.array([pj]), 10000.0)
        return float(jnp.sum(qr * kr))
    assert abs(dot_at(5, 3) - dot_at(12, 10)) < 1e-4
    assert abs(dot_at(5, 3) - dot_at(6, 3)) > 1e-4  # actually differs


# -------------------------------------------------------------- caches ----

def test_cached_attention_matches_full_recompute():
    B, S, H, Hkv, dh = 2, 40, 4, 2, 16
    ks = jax.random.split(jax.random.PRNGKey(2), 3)
    q_all = jax.random.normal(ks[0], (B, S, H, dh))
    k_all = jax.random.normal(ks[1], (B, S, Hkv, dh))
    v_all = jax.random.normal(ks[2], (B, S, Hkv, dh))
    ref = naive_attention(q_all, k_all, v_all)
    # simulate decode of last 3 tokens against a full cache
    C = 64
    cache_k = jnp.zeros((B, C, Hkv, dh)).at[:, :S - 3].set(k_all[:, :S - 3])
    cache_v = jnp.zeros((B, C, Hkv, dh)).at[:, :S - 3].set(v_all[:, :S - 3])
    cur = jnp.asarray(S - 3)
    cache_k = update_cache(cache_k, k_all[:, S - 3:], cur)
    cache_v = update_cache(cache_v, v_all[:, S - 3:], cur)
    out = cached_attention(q_all[:, S - 3:], cache_k, cache_v, cur)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref[:, S - 3:]),
                               atol=2e-5)


def test_rolling_window_cache_matches_window_attention():
    B, S, H, Hkv, dh, W = 1, 50, 2, 1, 16, 12
    margin = 8
    C = W + margin
    ks = jax.random.split(jax.random.PRNGKey(3), 3)
    q_all = jax.random.normal(ks[0], (B, S, H, dh))
    k_all = jax.random.normal(ks[1], (B, S, Hkv, dh))
    v_all = jax.random.normal(ks[2], (B, S, Hkv, dh))
    ref = naive_attention(q_all, k_all, v_all, window=W)
    cache_k = jnp.zeros((B, C, Hkv, dh))
    cache_v = jnp.zeros((B, C, Hkv, dh))
    outs = []
    for t in range(S):
        cur = jnp.asarray(t)
        cache_k = update_cache(cache_k, k_all[:, t:t + 1], cur, window=W)
        cache_v = update_cache(cache_v, v_all[:, t:t + 1], cur, window=W)
        outs.append(cached_attention(q_all[:, t:t + 1], cache_k, cache_v,
                                     cur, window=W))
    out = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5)


def test_per_row_cur_len_vector():
    """Ragged cur_len (B,) — each row masks its own length."""
    B, H, Hkv, dh, C = 3, 2, 1, 16, 32
    ks = jax.random.split(jax.random.PRNGKey(4), 3)
    k = jax.random.normal(ks[0], (B, C, Hkv, dh))
    v = jax.random.normal(ks[1], (B, C, Hkv, dh))
    q = jax.random.normal(ks[2], (B, 1, H, dh))
    cur = jnp.array([5, 17, 29])
    k2 = update_cache(k, jnp.ones((B, 1, Hkv, dh)), cur)
    out = cached_attention(q, k2, v, cur)
    # compare per row against scalar-cur computation
    for b in range(B):
        ob = cached_attention(q[b:b + 1], k2[b:b + 1], v[b:b + 1],
                              jnp.asarray(int(cur[b])))
        np.testing.assert_allclose(np.asarray(out[b]), np.asarray(ob[0]),
                                   atol=1e-5)
