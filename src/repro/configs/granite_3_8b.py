"""Granite-3.0 8B — dense GQA decoder.
[hf:ibm-granite/granite-3.0-2b-base family card]"""
from repro.configs.base import ArchConfig, AttnConfig

CONFIG = ArchConfig(
    name="granite-3-8b",
    family="dense",
    num_layers=40,
    d_model=4096,
    d_ff=12800,
    vocab_size=49155,
    attn=AttnConfig(num_heads=32, num_kv_heads=8, head_dim=128,
                    rope_theta=10000.0),
    tie_embeddings=True,
    citation="hf:ibm-granite/granite-3.0-2b-base (Granite 3.0 model card)",
)
