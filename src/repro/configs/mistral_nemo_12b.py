"""Mistral-NeMo 12B — dense GQA decoder, 128k context.
[hf:mistralai/Mistral-Nemo-Base-2407]"""
from repro.configs.base import ArchConfig, AttnConfig

CONFIG = ArchConfig(
    name="mistral-nemo-12b",
    family="dense",
    num_layers=40,
    d_model=5120,
    d_ff=14336,
    vocab_size=131072,
    attn=AttnConfig(num_heads=32, num_kv_heads=8, head_dim=128,
                    rope_theta=1000000.0),
    citation="hf:mistralai/Mistral-Nemo-Base-2407 (model card)",
)
