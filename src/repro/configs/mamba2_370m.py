"""Mamba2 370M — attention-free SSD state-space model. [arXiv:2405.21060]"""
from repro.configs.base import ArchConfig, SSMConfig

CONFIG = ArchConfig(
    name="mamba2-370m",
    family="ssm",
    num_layers=48,
    d_model=1024,
    d_ff=0,                      # attention-free, no dense MLP
    vocab_size=50280,
    ssm=SSMConfig(d_state=128, d_conv=4, expand=2, head_dim=64,
                  n_groups=1, chunk_size=256),
    citation="arXiv:2405.21060 (Transformers are SSMs / Mamba2 SSD)",
)
