"""Qwen3-MoE 235B-A22B — 128-expert top-8 MoE, GQA kv=4.
[hf:Qwen/Qwen3-30B-A3B family card]"""
from repro.configs.base import ArchConfig, AttnConfig, MoEConfig

CONFIG = ArchConfig(
    name="qwen3-moe-235b-a22b",
    family="moe",
    num_layers=94,
    d_model=4096,
    d_ff=0,                      # every FFN is MoE
    vocab_size=151936,
    attn=AttnConfig(num_heads=64, num_kv_heads=4, head_dim=128,
                    rope_theta=1000000.0, qk_norm=True),
    moe=MoEConfig(num_experts=128, top_k=8, d_ff_expert=1536,
                  normalize_gates=True),
    moe_every=1,
    citation="hf:Qwen/Qwen3-30B-A3B (Qwen3 MoE model card)",
)
