from repro.configs.base import (  # noqa: F401
    ArchConfig, AttnConfig, MoEConfig, SSMConfig, ShapeConfig, XSharePolicy,
    round_up,
)
