"""Architecture registry — maps public ``--arch`` ids to configs."""
from __future__ import annotations

from repro.configs.base import ArchConfig

from repro.configs import (
    llama3_8b,
    mamba2_370m,
    h2o_danube_1_8b,
    granite_3_8b,
    qwen3_moe_235b_a22b,
    mistral_nemo_12b,
    granite_moe_1b_a400m,
    zamba2_1_2b,
    paligemma_3b,
    musicgen_large,
    gpt_oss_120b_proxy,
    deepseek_r1_proxy,
)

# The 10 assigned architectures (dry-run matrix = these x 4 shapes).
ASSIGNED = (
    llama3_8b.CONFIG,
    mamba2_370m.CONFIG,
    h2o_danube_1_8b.CONFIG,
    granite_3_8b.CONFIG,
    qwen3_moe_235b_a22b.CONFIG,
    mistral_nemo_12b.CONFIG,
    granite_moe_1b_a400m.CONFIG,
    zamba2_1_2b.CONFIG,
    paligemma_3b.CONFIG,
    musicgen_large.CONFIG,
)

# The paper's own eval models (used by benchmarks; not in the dry-run matrix).
PAPER_MODELS = (
    gpt_oss_120b_proxy.CONFIG,
    deepseek_r1_proxy.CONFIG,
)

ARCHS = {c.name: c for c in ASSIGNED + PAPER_MODELS}


def get_config(name: str) -> ArchConfig:
    try:
        return ARCHS[name]
    except KeyError:
        raise KeyError(
            f"unknown arch {name!r}; available: {sorted(ARCHS)}") from None


def assigned_names():
    return [c.name for c in ASSIGNED]
