"""H2O-Danube 1.8B — llama+mistral mix with sliding-window attention.
[arXiv:2401.16818]"""
from repro.configs.base import ArchConfig, AttnConfig

CONFIG = ArchConfig(
    name="h2o-danube-1.8b",
    family="dense",
    num_layers=24,
    d_model=2560,
    d_ff=6912,
    vocab_size=32000,
    attn=AttnConfig(num_heads=32, num_kv_heads=8, head_dim=80,
                    rope_theta=10000.0, sliding_window=4096),
    citation="arXiv:2401.16818 (H2O-Danube-1.8B Technical Report)",
)
