"""MusicGen-large — decoder-only transformer over EnCodec RVQ tokens,
4 parallel codebooks (delay pattern), vocab 2048 per codebook. The EnCodec
conv codec + text conditioner are STUBBED per assignment: input_specs
provides precomputed conditioning frame embeddings. [arXiv:2306.05284]"""
from repro.configs.base import ArchConfig, AttnConfig

CONFIG = ArchConfig(
    name="musicgen-large",
    family="audio",
    num_layers=48,
    d_model=2048,
    d_ff=8192,
    vocab_size=2048,
    attn=AttnConfig(num_heads=32, num_kv_heads=32, head_dim=64,
                    rope_theta=10000.0),
    num_codebooks=4,
    prefix_len=64,               # stubbed conditioner embeddings
    act="gelu",
    vocab_pad_to=256,
    citation="arXiv:2306.05284 (Simple and Controllable Music Generation)",
)
