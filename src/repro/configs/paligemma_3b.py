"""PaliGemma 3B — gemma decoder backbone consuming SigLIP patch embeddings.
The SigLIP vision tower + projector are STUBBED per assignment: input_specs
provides precomputed patch embeddings (prefix_len x d_model).
[arXiv:2407.07726]"""
from repro.configs.base import ArchConfig, AttnConfig

CONFIG = ArchConfig(
    name="paligemma-3b",
    family="vlm",
    num_layers=18,
    d_model=2048,
    d_ff=16384,
    vocab_size=257216,
    attn=AttnConfig(num_heads=8, num_kv_heads=1, head_dim=256,
                    rope_theta=10000.0),
    prefix_len=256,              # 256 SigLIP patch embeddings (224px/14)
    act="gelu",
    tie_embeddings=True,
    citation="arXiv:2407.07726 (PaliGemma); SigLIP frontend stubbed",
)
