"""Granite-3.0 1B-A400M MoE — 32 experts top-8.
[hf:ibm-granite/granite-3.0-1b-a400m-base]"""
from repro.configs.base import ArchConfig, AttnConfig, MoEConfig

CONFIG = ArchConfig(
    name="granite-moe-1b-a400m",
    family="moe",
    num_layers=24,
    d_model=1024,
    d_ff=0,
    vocab_size=49155,
    attn=AttnConfig(num_heads=16, num_kv_heads=8, head_dim=64,
                    rope_theta=10000.0),
    moe=MoEConfig(num_experts=32, top_k=8, d_ff_expert=512,
                  normalize_gates=True),
    moe_every=1,
    tie_embeddings=True,
    citation="hf:ibm-granite/granite-3.0-1b-a400m-base (model card)",
)
