"""DeepSeek-R1 proxy — the paper's EP eval model: 256 routed experts top-8
plus 1 shared expert. MLA is proxied with GQA kv=16 (documented in
DESIGN.md §3); expert structure is exact. [arXiv:2501.12948]"""
from repro.configs.base import ArchConfig, AttnConfig, MoEConfig

CONFIG = ArchConfig(
    name="deepseek-r1-proxy",
    family="moe",
    num_layers=61,
    d_model=7168,
    d_ff=0,
    vocab_size=129280,
    attn=AttnConfig(num_heads=128, num_kv_heads=16, head_dim=128,
                    rope_theta=10000.0),
    moe=MoEConfig(num_experts=256, top_k=8, d_ff_expert=2048,
                  num_shared_experts=1, d_ff_shared=2048,
                  normalize_gates=True),
    moe_every=1,
    citation="arXiv:2501.12948 (DeepSeek-R1); paper EP eval model",
)
