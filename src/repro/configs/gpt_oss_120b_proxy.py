"""GPT-OSS 120B proxy — the paper's primary eval model: 128 routed experts,
top-4. Structure per the gpt-oss model card [arXiv:2508.10925]; used by the
paper-table benchmarks (reduced in smoke tests)."""
from repro.configs.base import ArchConfig, AttnConfig, MoEConfig

CONFIG = ArchConfig(
    name="gpt-oss-120b-proxy",
    family="moe",
    num_layers=36,
    d_model=2880,
    d_ff=0,
    vocab_size=201088,
    attn=AttnConfig(num_heads=64, num_kv_heads=8, head_dim=64,
                    rope_theta=150000.0),
    moe=MoEConfig(num_experts=128, top_k=4, d_ff_expert=2880,
                  normalize_gates=True),
    moe_every=1,
    citation="arXiv:2508.10925 (gpt-oss model card); paper eval model",
)
