"""Zamba2 1.2B — hybrid: Mamba2 backbone with a SHARED attention block
applied periodically (weights shared across applications). [arXiv:2411.15242]"""
from repro.configs.base import ArchConfig, AttnConfig, SSMConfig

CONFIG = ArchConfig(
    name="zamba2-1.2b",
    family="hybrid",
    num_layers=38,
    d_model=2048,
    d_ff=8192,                   # shared attn block's MLP
    vocab_size=32000,
    attn=AttnConfig(num_heads=32, num_kv_heads=32, head_dim=64,
                    rope_theta=10000.0),
    ssm=SSMConfig(d_state=64, d_conv=4, expand=2, head_dim=64,
                  n_groups=1, chunk_size=128),
    attn_every=6,                # shared attn block every 6 mamba layers
    shared_attn=True,
    citation="arXiv:2411.15242 (Zamba2 suite: Mamba2 + shared attention)",
)
