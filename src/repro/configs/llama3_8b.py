"""Llama-3 8B — dense GQA decoder, 128k vocab. [arXiv:2407.21783]"""
from repro.configs.base import ArchConfig, AttnConfig

CONFIG = ArchConfig(
    name="llama3-8b",
    family="dense",
    num_layers=32,
    d_model=4096,
    d_ff=14336,
    vocab_size=128256,
    attn=AttnConfig(num_heads=32, num_kv_heads=8, head_dim=128,
                    rope_theta=500000.0),
    citation="arXiv:2407.21783 (The Llama 3 Herd of Models)",
)
