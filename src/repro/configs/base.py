"""Architecture / shape configuration dataclasses.

Every assigned architecture gets one module in this package exporting
``CONFIG: ArchConfig``; ``registry.py`` collects them under their public
``--arch`` ids.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional, Tuple


def round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


@dataclass(frozen=True)
class AttnConfig:
    num_heads: int
    num_kv_heads: int
    head_dim: int
    rope_theta: float = 10000.0
    sliding_window: Optional[int] = None  # None = full causal attention
    qk_norm: bool = False


@dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int
    d_ff_expert: int
    num_shared_experts: int = 0  # DeepSeek-style always-on experts
    d_ff_shared: int = 0
    router_bias: bool = False
    # router softmax over the selected set (Mixtral/DSv3 style)
    normalize_gates: bool = True


@dataclass(frozen=True)
class SSMConfig:
    """Mamba2 (SSD) block configuration."""
    d_state: int = 128
    d_conv: int = 4
    expand: int = 2
    head_dim: int = 64
    n_groups: int = 1
    chunk_size: int = 256


@dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str  # dense | moe | ssm | hybrid | vlm | audio
    num_layers: int
    d_model: int
    d_ff: int              # dense-MLP hidden size (0 for attn-free / pure-MoE FFN)
    vocab_size: int
    attn: Optional[AttnConfig] = None
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    # hybrid: apply the shared attention block every `attn_every` ssm layers
    attn_every: int = 0
    shared_attn: bool = False
    # moe: apply MoE FFN every `moe_every` layers (1 = every layer)
    moe_every: int = 1
    # modality frontend stubs (vlm/audio): length of precomputed
    # frame/patch embeddings prepended to the token sequence at prefill
    prefix_len: int = 0
    # audio: number of parallel codebook streams (embeddings summed,
    # one LM head per codebook)
    num_codebooks: int = 1
    act: str = "swiglu"    # swiglu | gelu
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    vocab_pad_to: int = 256
    citation: str = ""

    @property
    def padded_vocab(self) -> int:
        return round_up(self.vocab_size, self.vocab_pad_to)

    @property
    def has_moe(self) -> bool:
        return self.moe is not None

    @property
    def has_attention(self) -> bool:
        return self.attn is not None

    def layer_kinds(self) -> Tuple[str, ...]:
        """Per-layer block kind, length == num_layers.

        dense/vlm/audio -> ('attn_mlp',)*L ; moe -> attn + MoE FFN;
        ssm -> ('ssm',)*L ; hybrid -> ssm with a shared attn block
        applied every `attn_every` layers (weights shared).
        """
        if self.family in ("dense", "vlm", "audio"):
            return ("attn_mlp",) * self.num_layers
        if self.family == "moe":
            kinds = []
            for i in range(self.num_layers):
                kinds.append("attn_moe" if (i % self.moe_every == 0) else "attn_mlp")
            return tuple(kinds)
        if self.family == "ssm":
            return ("ssm",) * self.num_layers
        if self.family == "hybrid":
            return ("ssm",) * self.num_layers  # shared attn handled in-model
        raise ValueError(f"unknown family {self.family}")

    def reduced(self, *, num_layers: int = 2, max_d_model: int = 512,
                max_experts: int = 4, max_vocab: int = 1024) -> "ArchConfig":
        """Smoke-test variant: same family/topology, tiny dims."""
        d_model = min(self.d_model, max_d_model)
        changes = dict(
            name=self.name + "-reduced",
            num_layers=num_layers,
            d_model=d_model,
            d_ff=min(self.d_ff, 2 * d_model) if self.d_ff else 0,
            vocab_size=min(self.vocab_size, max_vocab),
            prefix_len=min(self.prefix_len, 8),
        )
        if self.attn is not None:
            heads = max(2, min(self.attn.num_heads, d_model // 64))
            kv = max(1, min(self.attn.num_kv_heads, heads))
            while heads % kv:
                kv -= 1
            changes["attn"] = dataclasses.replace(
                self.attn, num_heads=heads, num_kv_heads=kv,
                head_dim=d_model // heads,
                sliding_window=(64 if self.attn.sliding_window else None))
        if self.moe is not None:
            ne = min(self.moe.num_experts, max_experts)
            changes["moe"] = dataclasses.replace(
                self.moe, num_experts=ne, top_k=min(self.moe.top_k, max(1, ne // 2)),
                d_ff_expert=min(self.moe.d_ff_expert, d_model),
                d_ff_shared=min(self.moe.d_ff_shared, d_model))
        if self.ssm is not None:
            changes["ssm"] = dataclasses.replace(
                self.ssm, d_state=min(self.ssm.d_state, 16), head_dim=32,
                chunk_size=32)
        if self.attn_every:
            changes["attn_every"] = min(self.attn_every, num_layers)
        return dataclasses.replace(self, **changes)


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode
    # decode: size of the KV/rolling cache backing each sequence
    cache_len: int = 0
    # speculative decoding verify width (tokens per request incl. base)
    spec_len: int = 0


@dataclass(frozen=True)
class EPConfig:
    """Expert-parallel execution knobs (ep/ package).

    num_shards:   EP mesh width (devices on the "model" axis).
    replicate_hot: hottest experts replicated this many ways; their
                  rows split across replicas by token-id modulus.
    max_replicas: per-expert replica cap (None = num_shards).
    rebalance_hysteresis: between-batch placement moves are adopted
                  only when the predicted peak load improves by more
                  than this relative fraction — weight redistribution
                  isn't free, so placement must not thrash.
    max_rows:     per-peer payload rows for the ragged all-to-all.
                  None = worst case (always exact); "auto" = counts
                  exchange first, pad to the per-round max (pow2
                  bucketed); int = hard clamp with GShard drop
                  semantics.
    block_t:      row-tile size of the per-shard grouped GEMM
                  (None = heuristic from dispatch.default_block_t).
    """
    num_shards: int = 8
    replicate_hot: int = 0
    max_replicas: Optional[int] = None
    rebalance_hysteresis: float = 0.1
    max_rows: object = None  # None | "auto" | int
    block_t: Optional[int] = None


@dataclass(frozen=True)
class XSharePolicy:
    """Inference-time batch-aware expert-selection policy (the paper).

    mode:
      off        - vanilla per-token top-k routing
      batch      - Algorithm 2 (warm-up k0, batch budget m_l, refinement)
      spec       - Algorithm 4 (per-request budget m_r, then batch greedy)
      ep         - Algorithm 6 (per-device-group budget m_g)
    Budgets follow the paper's convention: the final set is
    warmup ∪ top-m(extra), so m counts experts added *beyond* warm-up.
    """
    mode: str = "off"
    k0: int = 1          # warm-up per-token top-k0
    m_l: int = 0         # batch budget (experts added beyond warm-up)
    m_r: int = 0         # per-request budget (spec mode)
    m_g: int = 0         # per-device-group budget (ep mode)
    num_groups: int = 8  # EP group count G
    strict_cap: bool = True  # ep: cap warm-up experts at m_g per group too
    # spec: weight of the cross-pass correlation prior (per-request gate
    # histograms collected by the scheduler) blended into Algorithm-4
    # selection scores; 0 disables the prior entirely.
    corr: float = 1.0
