"""Activation-sharding constraints usable from model code without
threading mesh handles everywhere.

Model code calls ``constrain(x, "batch", None, "model", ...)``; under a
configured mesh context (launch/dryrun/train) this becomes
``with_sharding_constraint`` with "batch" resolved to the configured
data axes tuple; outside any context (CPU unit tests) it is a no-op.
"""
from __future__ import annotations

import contextlib
from typing import Optional, Tuple

import jax
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

_STATE = {"mesh": None, "batch_axes": (), "disabled": frozenset()}


@contextlib.contextmanager
def mesh_context(mesh, batch_axes: Tuple[str, ...],
                 disable: Tuple[str, ...] = ()):
    old = dict(_STATE)
    _STATE["mesh"] = mesh
    _STATE["batch_axes"] = tuple(batch_axes)
    _STATE["disabled"] = frozenset(disable)
    try:
        yield
    finally:
        _STATE.update(old)


def current_mesh():
    return _STATE["mesh"]


def batch_axes() -> Tuple[str, ...]:
    return _STATE["batch_axes"]


def model_axis_size() -> int:
    """Extent of the "model" mesh axis (1 outside any mesh context).

    The expert axis shards contiguously over "model", so this is also
    the number of expert-parallel shards: sorted dispatch rounds its
    tile count to a multiple of it and constrains the tile axis over
    "model" (expert-contiguous tiles => per-shard segments)."""
    mesh = _STATE["mesh"]
    if mesh is None:
        return 1
    return dict(zip(mesh.axis_names, mesh.devices.shape)).get("model", 1)


def get_shard_map():
    """The shard_map entry point across jax versions: ``jax.shard_map``
    in newer releases, the experimental module before that."""
    sm = getattr(jax, "shard_map", None)
    if sm is not None:
        return sm
    from jax.experimental.shard_map import shard_map
    return shard_map


def make_ep_mesh(num_shards: Optional[int] = None, axis: str = "model"):
    """A 1-D expert-parallel mesh over the first ``num_shards`` local
    devices (all of them by default). The axis name defaults to "model"
    — the axis the expert dimension shards over everywhere else in the
    repo — so EP composes with the existing partition rules."""
    n = len(jax.devices()) if num_shards is None else num_shards
    if n > len(jax.devices()):
        raise ValueError(
            f"make_ep_mesh({n}) but only {len(jax.devices())} devices "
            f"visible (set XLA_FLAGS=--xla_force_host_platform_device_"
            f"count=N before importing jax to emulate)")
    from jax.sharding import Mesh
    return Mesh(np.asarray(jax.devices()[:n]).reshape(n), (axis,))


def constrain(x, *axes: Optional[str], tag: Optional[str] = None):
    """axes entries: None, "model", or "batch" (mapped to the configured
    data-parallel axes tuple). Tagged constraints can be disabled per
    mesh_context (perf experiments, e.g. tag="seqpar")."""
    mesh = _STATE["mesh"]
    if mesh is None or (tag and tag in _STATE["disabled"]):
        return x
    resolved = []
    for a in axes:
        if a == "batch":
            ba = _STATE["batch_axes"]
            resolved.append(ba if ba else None)
        else:
            resolved.append(a)
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, P(*resolved)))
