"""Activation-sharding constraints usable from model code without
threading mesh handles everywhere.

Model code calls ``constrain(x, "batch", None, "model", ...)``; under a
configured mesh context (launch/dryrun/train) this becomes
``with_sharding_constraint`` with "batch" resolved to the configured
data axes tuple; outside any context (CPU unit tests) it is a no-op.
"""
from __future__ import annotations

import contextlib
from typing import Optional, Tuple

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

_STATE = {"mesh": None, "batch_axes": (), "disabled": frozenset()}


@contextlib.contextmanager
def mesh_context(mesh, batch_axes: Tuple[str, ...],
                 disable: Tuple[str, ...] = ()):
    old = dict(_STATE)
    _STATE["mesh"] = mesh
    _STATE["batch_axes"] = tuple(batch_axes)
    _STATE["disabled"] = frozenset(disable)
    try:
        yield
    finally:
        _STATE.update(old)


def current_mesh():
    return _STATE["mesh"]


def batch_axes() -> Tuple[str, ...]:
    return _STATE["batch_axes"]


def model_axis_size() -> int:
    """Extent of the "model" mesh axis (1 outside any mesh context).

    The expert axis shards contiguously over "model", so this is also
    the number of expert-parallel shards: sorted dispatch rounds its
    tile count to a multiple of it and constrains the tile axis over
    "model" (expert-contiguous tiles => per-shard segments)."""
    mesh = _STATE["mesh"]
    if mesh is None:
        return 1
    return dict(zip(mesh.axis_names, mesh.devices.shape)).get("model", 1)


def constrain(x, *axes: Optional[str], tag: Optional[str] = None):
    """axes entries: None, "model", or "batch" (mapped to the configured
    data-parallel axes tuple). Tagged constraints can be disabled per
    mesh_context (perf experiments, e.g. tag="seqpar")."""
    mesh = _STATE["mesh"]
    if mesh is None or (tag and tag in _STATE["disabled"]):
        return x
    resolved = []
    for a in axes:
        if a == "batch":
            ba = _STATE["batch_axes"]
            resolved.append(ba if ba else None)
        else:
            resolved.append(a)
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, P(*resolved)))
