"""Pallas TPU kernel: flash-decode GQA — one query token per sequence
scored against a long KV cache, online softmax over sequence blocks.

Grid: (batch, seq blocks). The KV cache never materializes an (S,) score
tensor in HBM; each step streams one (block_s, Hkv, dh) tile of K and V
through VMEM and keeps the (H,) running max / normalizer / accumulator
in VMEM scratch. This is the decode-phase memory-bound hot loop the
paper's setting lives in: per step, bytes = KV-cache traffic, so the
roofline memory term tracks cache size directly.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.compat import resolve_interpret, tpu_compiler_params

_NEG = -1e30


def _kernel(len_ref, q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
            block_s: int, num_blocks: int, rep: int):
    b = pl.program_id(0)
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, _NEG)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0].astype(jnp.float32)                     # (H, dh)
    k = k_ref[0].astype(jnp.float32)                     # (bs, Hkv, dh)
    v = v_ref[0].astype(jnp.float32)
    H, dh = q.shape
    Hkv = k.shape[1]
    qg = q.reshape(Hkv, rep, dh)
    s = jnp.einsum("grd,sgd->grs", qg, k) / math.sqrt(dh)  # (Hkv,rep,bs)
    s = s.reshape(H, -1)                                  # (H, bs)
    cols = j * block_s + jax.lax.broadcasted_iota(jnp.int32, (1, block_s), 1)
    mask = cols < len_ref[b]
    maskf = mask.astype(jnp.float32)
    s = jnp.where(mask, s, _NEG)

    m_prev = m_scr[...]                                   # (H, 1)
    m_new = jnp.maximum(m_prev, s.max(axis=-1, keepdims=True))
    p = jnp.exp(s - m_new) * maskf                        # (H, bs)
    alpha = jnp.exp(m_prev - m_new)                       # (H, 1)
    pv = jnp.einsum("grs,gsd->grd", p.reshape(Hkv, rep, -1),
                    v.transpose(1, 0, 2))                 # (Hkv,rep,dh)
    acc_scr[...] = acc_scr[...] * alpha + pv.reshape(H, dh)
    l_scr[...] = l_scr[...] * alpha + p.sum(axis=-1, keepdims=True)
    m_scr[...] = m_new

    @pl.when(j == num_blocks - 1)
    def _emit():
        o_ref[0] = (acc_scr[...] /
                    jnp.maximum(l_scr[...], 1e-30)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_s", "interpret"))
def decode_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                     lengths: jnp.ndarray, *, block_s: int = 512,
                     interpret=None) -> jnp.ndarray:
    """q: (B, H, dh); k/v: (B, S, Hkv, dh); lengths: (B,) valid lengths.

    Returns (B, H, dh). See ref.decode_attn_ref.
    """
    interpret = resolve_interpret(interpret)
    B, H, dh = q.shape
    S, Hkv = k.shape[1], k.shape[2]
    rep = H // Hkv
    bs = min(block_s, S)
    Sp = ((S + bs - 1) // bs) * bs
    if Sp != S:
        k = jnp.pad(k, ((0, 0), (0, Sp - S), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, Sp - S), (0, 0), (0, 0)))
    nb = Sp // bs

    out = pl.pallas_call(
        functools.partial(_kernel, block_s=bs, num_blocks=nb, rep=rep),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=(B, nb),
            in_specs=[
                pl.BlockSpec((1, H, dh), lambda b, j, lens: (b, 0, 0)),
                pl.BlockSpec((1, bs, Hkv, dh),
                             lambda b, j, lens: (b, j, 0, 0)),
                pl.BlockSpec((1, bs, Hkv, dh),
                             lambda b, j, lens: (b, j, 0, 0)),
            ],
            out_specs=pl.BlockSpec((1, H, dh), lambda b, j, lens: (b, 0, 0)),
            scratch_shapes=[
                pltpu.VMEM((H, 1), jnp.float32),
                pltpu.VMEM((H, 1), jnp.float32),
                pltpu.VMEM((H, dh), jnp.float32),
            ],
        ),
        out_shape=jax.ShapeDtypeStruct((B, H, dh), q.dtype),
        interpret=interpret,
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "arbitrary")),
    )(lengths.astype(jnp.int32), q, k, v)
    return out
