"""Pallas TPU kernel: XShare masked grouped expert FFN.

This is where the paper's memory-IO saving becomes *structural* on TPU:
the grid iterates over the XShare-selected expert slots (a static budget
`max_active`, not all E experts), and the weight BlockSpec index maps are
functions of a scalar-prefetched `expert_ids` vector. An expert outside
the selected set is therefore never DMA'd from HBM to VMEM at all —
per-step expert-weight traffic is max_active * 3*d*f bytes instead of
E * 3*d*f, the TPU-native analogue of the paper's "fewer experts loaded
from GPU memory".

Grid: (max_active, d_ff tiles). The FFN hidden axis is tiled so each
step's working set (x tile + 3 weight tiles + accumulator) fits VMEM;
tile sizes default to MXU-aligned multiples of 128.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.compat import tpu_compiler_params


def _kernel(ids_ref, valid_ref, x_ref, w1_ref, w3_ref, w2_ref, comb_ref,
            o_ref, acc_ref, *, num_f_tiles: int):
    slot = pl.program_id(0)
    fi = pl.program_id(1)

    @pl.when(fi == 0)
    def _init_acc():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    @pl.when(valid_ref[slot] > 0)
    def _compute():
        xb = x_ref[...].astype(jnp.float32)               # (T, d)
        h = xb @ w1_ref[0].astype(jnp.float32)            # (T, bf)
        g = xb @ w3_ref[0].astype(jnp.float32)
        h = jax.nn.silu(h) * g
        y = h @ w2_ref[0].astype(jnp.float32)             # (T, d)
        acc_ref[...] += comb_ref[...].astype(jnp.float32) * y

    @pl.when(fi == num_f_tiles - 1)
    def _emit():
        # accumulate this expert's contribution into the output
        @pl.when(slot == 0)
        def _():
            o_ref[...] = jnp.zeros_like(o_ref)
        o_ref[...] += acc_ref[...].astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("max_active", "block_f",
                                             "interpret"))
def moe_ffn(x: jnp.ndarray, w1: jnp.ndarray, w3: jnp.ndarray,
            w2: jnp.ndarray, combine: jnp.ndarray, active: jnp.ndarray, *,
            max_active: int, block_f: int = 512,
            interpret: bool = True) -> jnp.ndarray:
    """XShare masked expert FFN. See ref.moe_ffn_ref for semantics.

    max_active: static upper bound on |selected set| (the XShare budget
    bound k0*T + m_l, capped at E). Weight HBM traffic scales with this,
    not with E.
    """
    T, d = x.shape
    E, _, f = w1.shape
    max_active = min(max_active, E)
    bf = min(block_f, f)
    assert f % bf == 0, (f, bf)
    nf = f // bf

    ids = jnp.nonzero(active, size=max_active, fill_value=0)[0]
    ids = ids.astype(jnp.int32)
    valid = (jnp.arange(max_active) < active.sum()).astype(jnp.int32)

    grid = (max_active, nf)
    out = pl.pallas_call(
        functools.partial(_kernel, num_f_tiles=nf),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,
            grid=grid,
            in_specs=[
                pl.BlockSpec((T, d), lambda s, fi, ids, valid: (0, 0)),
                pl.BlockSpec((1, d, bf),
                             lambda s, fi, ids, valid: (ids[s], 0, fi)),
                pl.BlockSpec((1, d, bf),
                             lambda s, fi, ids, valid: (ids[s], 0, fi)),
                pl.BlockSpec((1, bf, d),
                             lambda s, fi, ids, valid: (ids[s], fi, 0)),
                pl.BlockSpec((T, 1),
                             lambda s, fi, ids, valid: (0, ids[s])),
            ],
            out_specs=pl.BlockSpec((T, d), lambda s, fi, ids, valid: (0, 0)),
            scratch_shapes=[pltpu.VMEM((T, d), jnp.float32)],
        ),
        out_shape=jax.ShapeDtypeStruct((T, d), x.dtype),
        interpret=interpret,
        compiler_params=tpu_compiler_params(
            dimension_semantics=("arbitrary", "arbitrary")),
    )(ids, valid, x, w1, w3, w2, combine)
    return out
