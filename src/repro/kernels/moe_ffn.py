"""Pallas TPU kernels: XShare masked expert FFN (dense combine) and the
sort-based grouped-GEMM expert FFN.

This is where the paper's memory-IO saving becomes *structural* on TPU:
the grid iterates over occupied expert work (a static budget, not all E
experts), and the weight BlockSpec index maps are functions of
scalar-prefetched expert-id vectors. An expert outside the selected /
routed set is therefore never DMA'd from HBM to VMEM at all — per-step
expert-weight traffic scales with the XShare-selected set, not with E,
the TPU-native analogue of the paper's "fewer experts loaded from GPU
memory".

Two kernels:

``moe_ffn``     — every expert runs over the whole (T, d) block and the
                  combine matrix masks; right for decode-sized T where
                  one x block fits VMEM and most tokens hit most active
                  experts. Grid: (max_active, d_ff tiles).

``grouped_ffn`` — the prefill-scale path. Tokens arrive pre-sorted into
                  expert-contiguous order, each expert's segment padded
                  to a multiple of ``block_t`` (models/dispatch.py
                  builds that layout with an argsort + bincount/cumsum).
                  The grid iterates over occupied row tiles via a
                  scalar-prefetched per-tile expert-id vector computed
                  from the segment offsets, so each token row is
                  touched once and each occupied expert's weights are
                  DMA'd once per f-tile — compute and weight traffic
                  are both capacity-free. Grid: (row tiles, d_ff tiles).

The FFN hidden axis is tiled so each step's working set (x tile + 3
weight tiles + accumulator) fits VMEM; tile sizes default to
MXU-aligned multiples of 128.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.compat import resolve_interpret, tpu_compiler_params


def _kernel(ids_ref, valid_ref, x_ref, w1_ref, w3_ref, w2_ref, comb_ref,
            o_ref, acc_ref, *, num_f_tiles: int):
    slot = pl.program_id(0)
    fi = pl.program_id(1)

    @pl.when(fi == 0)
    def _init_acc():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    @pl.when(valid_ref[slot] > 0)
    def _compute():
        xb = x_ref[...].astype(jnp.float32)               # (T, d)
        h = xb @ w1_ref[0].astype(jnp.float32)            # (T, bf)
        g = xb @ w3_ref[0].astype(jnp.float32)
        h = jax.nn.silu(h) * g
        y = h @ w2_ref[0].astype(jnp.float32)             # (T, d)
        acc_ref[...] += comb_ref[...].astype(jnp.float32) * y

    @pl.when(fi == num_f_tiles - 1)
    def _emit():
        # accumulate this expert's contribution into the output
        @pl.when(slot == 0)
        def _():
            o_ref[...] = jnp.zeros_like(o_ref)
        o_ref[...] += acc_ref[...].astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("max_active", "block_f",
                                             "interpret"))
def moe_ffn(x: jnp.ndarray, w1: jnp.ndarray, w3: jnp.ndarray,
            w2: jnp.ndarray, combine: jnp.ndarray, active: jnp.ndarray, *,
            max_active: int, block_f: int = 512,
            interpret: Optional[bool] = None) -> jnp.ndarray:
    """XShare masked expert FFN. See ref.moe_ffn_ref for semantics.

    max_active: static upper bound on |selected set| (the XShare budget
    bound k0*T + m_l, capped at E). Weight HBM traffic scales with this,
    not with E.
    """
    interpret = resolve_interpret(interpret)
    T, d = x.shape
    E, _, f = w1.shape
    max_active = min(max_active, E)
    bf = min(block_f, f)
    assert f % bf == 0, (f, bf)
    nf = f // bf

    ids = jnp.nonzero(active, size=max_active, fill_value=0)[0]
    ids = ids.astype(jnp.int32)
    valid = (jnp.arange(max_active) < active.sum()).astype(jnp.int32)

    grid = (max_active, nf)
    out = pl.pallas_call(
        functools.partial(_kernel, num_f_tiles=nf),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,
            grid=grid,
            in_specs=[
                pl.BlockSpec((T, d), lambda s, fi, ids, valid: (0, 0)),
                pl.BlockSpec((1, d, bf),
                             lambda s, fi, ids, valid: (ids[s], 0, fi)),
                pl.BlockSpec((1, d, bf),
                             lambda s, fi, ids, valid: (ids[s], 0, fi)),
                pl.BlockSpec((1, bf, d),
                             lambda s, fi, ids, valid: (ids[s], fi, 0)),
                pl.BlockSpec((T, 1),
                             lambda s, fi, ids, valid: (0, ids[s])),
            ],
            out_specs=pl.BlockSpec((T, d), lambda s, fi, ids, valid: (0, 0)),
            scratch_shapes=[pltpu.VMEM((T, d), jnp.float32)],
        ),
        out_shape=jax.ShapeDtypeStruct((T, d), x.dtype),
        interpret=interpret,
        compiler_params=tpu_compiler_params(
            dimension_semantics=("arbitrary", "arbitrary")),
    )(ids, valid, x, w1, w3, w2, combine)
    return out


# -------------------------------------------------- grouped (sorted) ------

def _grouped_kernel(eid_ref, valid_ref, xs_ref, w1_ref, w3_ref, w2_ref,
                    o_ref, acc_ref, *, num_f_tiles: int):
    ti = pl.program_id(0)
    fi = pl.program_id(1)

    @pl.when(fi == 0)
    def _init_acc():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    @pl.when(valid_ref[ti] > 0)
    def _compute():
        xb = xs_ref[...].astype(jnp.float32)              # (bt, d)
        h = xb @ w1_ref[0].astype(jnp.float32)            # (bt, bf)
        g = xb @ w3_ref[0].astype(jnp.float32)
        h = jax.nn.silu(h) * g
        acc_ref[...] += h @ w2_ref[0].astype(jnp.float32)  # (bt, d)

    @pl.when(fi == num_f_tiles - 1)
    def _emit():
        # each row tile owns its output block; padding / out-of-range
        # tiles never accumulated, so they emit the zero-initialized acc
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_t", "block_f",
                                             "interpret"))
def grouped_ffn(xs: jnp.ndarray, w1: jnp.ndarray, w3: jnp.ndarray,
                w2: jnp.ndarray, tile_eid: jnp.ndarray,
                tile_valid: jnp.ndarray, *, block_t: int,
                block_f: int = 512,
                interpret: Optional[bool] = None) -> jnp.ndarray:
    """Grouped expert FFN over an expert-sorted, tile-padded row layout.

    xs: (P, d) token rows gathered into expert-contiguous order, each
    expert's segment zero-padded to a multiple of block_t (P itself a
    multiple of block_t). tile_eid: (P/block_t,) int32 — the expert
    owning each row tile (clamped into [0, E) for padding tiles);
    tile_valid: (P/block_t,) int32 — 0 for tiles past the last occupied
    segment (their rows emit zeros and their weight blocks resolve to
    tile_eid's clamped id, so unrouted experts cost no HBM traffic).

    Returns ys (P, d): ys[i] = FFN_{expert(i)}(xs[i]). Gate weighting
    and the scatter back to token order happen outside (the combine is
    a (T*k,)-sized scatter-add, not a (T, E, C) einsum).
    """
    interpret = resolve_interpret(interpret)
    P, d = xs.shape
    E, _, f = w1.shape
    assert P % block_t == 0, (P, block_t)
    nt = P // block_t
    assert tile_eid.shape == (nt,), (tile_eid.shape, nt)
    bf = min(block_f, f)
    assert f % bf == 0, (f, bf)
    nf = f // bf

    grid = (nt, nf)
    out = pl.pallas_call(
        functools.partial(_grouped_kernel, num_f_tiles=nf),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,
            grid=grid,
            in_specs=[
                pl.BlockSpec((block_t, d),
                             lambda t, fi, eid, valid: (t, 0)),
                pl.BlockSpec((1, d, bf),
                             lambda t, fi, eid, valid: (eid[t], 0, fi)),
                pl.BlockSpec((1, d, bf),
                             lambda t, fi, eid, valid: (eid[t], 0, fi)),
                pl.BlockSpec((1, bf, d),
                             lambda t, fi, eid, valid: (eid[t], fi, 0)),
            ],
            out_specs=pl.BlockSpec((block_t, d),
                                   lambda t, fi, eid, valid: (t, 0)),
            scratch_shapes=[pltpu.VMEM((block_t, d), jnp.float32)],
        ),
        out_shape=jax.ShapeDtypeStruct((P, d), xs.dtype),
        interpret=interpret,
        compiler_params=tpu_compiler_params(
            dimension_semantics=("arbitrary", "arbitrary")),
    )(tile_eid.astype(jnp.int32), tile_valid.astype(jnp.int32),
      xs, w1, w3, w2)
    return out


def grouped_ffn_apply(xs: jnp.ndarray, w1: jnp.ndarray, w3: jnp.ndarray,
                      w2: jnp.ndarray, plan, *,
                      use_kernel: Optional[bool] = None,
                      block_f: int = 512) -> jnp.ndarray:
    """The one resolution point for "Pallas grouped_ffn or tile-gather
    einsum?" over a DispatchPlan layout — shared by the single-device
    sorted pipeline (models/dispatch.sorted_expert_ffn) and the
    per-shard grouped GEMM inside the shard_map EP executor
    (ep/executor.py), so both paths pick the same backend the same way.

    use_kernel: None = auto (the Pallas kernel wherever it would
    compile, i.e. not interpret mode; the jnp tile-gather einsum
    elsewhere), True/False forces.
    """
    if use_kernel is None:
        use_kernel = not resolve_interpret(None)
    if use_kernel:
        return grouped_ffn(xs, w1, w3, w2, plan.tile_eid, plan.tile_valid,
                           block_t=plan.block_t,
                           block_f=min(block_f, w1.shape[2]))
    from repro.models.dispatch import grouped_ffn_jnp
    return grouped_ffn_jnp(xs, w1, w3, w2, plan)
