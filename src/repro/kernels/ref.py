"""Pure-jnp oracles for every Pallas kernel (allclose targets)."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp


def moe_ffn_ref(x: jnp.ndarray, w1: jnp.ndarray, w3: jnp.ndarray,
                w2: jnp.ndarray, combine: jnp.ndarray,
                active: jnp.ndarray) -> jnp.ndarray:
    """XShare masked expert FFN.

    x: (T, d); w1/w3: (E, d, f); w2: (E, f, d); combine: (T, E) gate
    weights (0 = token not routed to expert); active: (E,) bool — the
    XShare-selected set. y = sum_e active_e * combine[:, e] * FFN_e(x).
    """
    xf = jnp.asarray(x, jnp.float32)
    h = jnp.einsum("td,edf->etf", xf, jnp.asarray(w1, jnp.float32))
    g = jnp.einsum("td,edf->etf", xf, jnp.asarray(w3, jnp.float32))
    h = jax.nn.silu(h) * g
    y_e = jnp.einsum("etf,efd->etd", h, jnp.asarray(w2, jnp.float32))
    w = jnp.where(active[:, None], combine.T, 0.0)          # (E, T)
    y = jnp.einsum("etd,et->td", y_e, jnp.asarray(w, jnp.float32))
    return y.astype(x.dtype)


def decode_attn_ref(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                    lengths: jnp.ndarray) -> jnp.ndarray:
    """Flash-decode oracle: one query token per sequence vs a KV cache.

    q: (B, H, dh); k/v: (B, S, Hkv, dh); lengths: (B,) valid cache length.
    """
    B, H, dh = q.shape
    Hkv = k.shape[2]
    rep = H // Hkv
    qf = jnp.asarray(q, jnp.float32)
    kf = jnp.repeat(jnp.asarray(k, jnp.float32), rep, axis=2)
    vf = jnp.repeat(jnp.asarray(v, jnp.float32), rep, axis=2)
    s = jnp.einsum("bhd,bshd->bhs", qf, kf) / jnp.sqrt(float(dh))
    mask = jnp.arange(k.shape[1])[None, :] < lengths[:, None]   # (B,S)
    s = jnp.where(mask[:, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhs,bshd->bhd", p, vf)
    return out.astype(q.dtype)


def ssd_chunk_ref(x: jnp.ndarray, dt: jnp.ndarray, A: jnp.ndarray,
                  Bm: jnp.ndarray, Cm: jnp.ndarray,
                  init_state: Optional[jnp.ndarray] = None):
    """Sequential SSM recurrence oracle for the SSD kernel.

    x: (B,S,nh,hd); dt: (B,S,nh); A: (nh,); Bm/Cm: (B,S,nh,ds)
    (already broadcast over groups). Returns (y (B,S,nh,hd),
    final_state (B,nh,hd,ds)).
    """
    Bsz, S, nh, hd = x.shape
    ds = Bm.shape[-1]
    st = jnp.zeros((Bsz, nh, hd, ds), jnp.float32) if init_state is None \
        else jnp.asarray(init_state, jnp.float32)

    def step(st, inp):
        xt, dtt, bt, ct = inp
        dA = jnp.exp(dtt * A)                              # (B,nh)
        st = st * dA[:, :, None, None] + jnp.einsum(
            "bh,bhp,bhs->bhps", dtt, xt, bt)
        y = jnp.einsum("bhs,bhps->bhp", ct, st)
        return st, y

    xs = (jnp.asarray(x, jnp.float32).transpose(1, 0, 2, 3),
          jnp.asarray(dt, jnp.float32).transpose(1, 0, 2),
          jnp.asarray(Bm, jnp.float32).transpose(1, 0, 2, 3),
          jnp.asarray(Cm, jnp.float32).transpose(1, 0, 2, 3))
    st, ys = jax.lax.scan(step, st, xs)
    return ys.transpose(1, 0, 2, 3).astype(x.dtype), st
