"""Public jit'd wrappers around the Pallas kernels, plus byte-traffic
models used by the roofline analysis and OTPS modeling.

``interpret`` defaults to None everywhere = auto-detect (compiled on
TPU, Python interpreter elsewhere; REPRO_PALLAS_INTERPRET overrides —
see kernels/compat.resolve_interpret).
"""
from __future__ import annotations

from typing import Optional

import jax.numpy as jnp

from repro.kernels.decode_attn import decode_attention
from repro.kernels.moe_ffn import grouped_ffn, moe_ffn
from repro.kernels.ssd_scan import ssd_scan

__all__ = ["xshare_moe_ffn", "xshare_grouped_ffn", "flash_decode",
           "ssd_chunk_scan", "moe_step_bytes", "dispatch_einsum_bytes",
           "dispatch_sorted_bytes"]


def xshare_moe_ffn(x, w1, w3, w2, combine, active, *,
                   max_active: Optional[int] = None, block_f: int = 512,
                   interpret: Optional[bool] = None):
    """Masked expert FFN; weight HBM traffic ~ max_active, not E."""
    E = w1.shape[0]
    ma = E if max_active is None else min(max_active, E)
    bf = block_f
    while w1.shape[2] % bf:
        bf //= 2
    return moe_ffn(x, w1, w3, w2, combine, active, max_active=ma,
                   block_f=bf, interpret=interpret)


def xshare_grouped_ffn(xs, w1, w3, w2, tile_eid, tile_valid, *,
                       block_t: int, block_f: int = 512,
                       interpret: Optional[bool] = None):
    """Sort-based grouped expert FFN over a tile-padded sorted layout
    (models/dispatch.py builds it); weight HBM traffic ~ occupied
    experts, compute ~ routed rows — both capacity-free."""
    bf = min(block_f, w1.shape[2])
    while w1.shape[2] % bf:
        bf //= 2
    return grouped_ffn(xs, w1, w3, w2, tile_eid, tile_valid,
                       block_t=block_t, block_f=bf, interpret=interpret)


def flash_decode(q, k, v, lengths, *, block_s: int = 512,
                 interpret: Optional[bool] = None):
    return decode_attention(q, k, v, lengths, block_s=block_s,
                            interpret=interpret)


def ssd_chunk_scan(x, dt, A, Bm, Cm, *, chunk: int = 128, block_h: int = 8,
                   interpret: Optional[bool] = None):
    bh = block_h
    while x.shape[2] % bh:
        bh //= 2
    return ssd_scan(x, dt, A, Bm, Cm, chunk=chunk, block_h=bh,
                    interpret=interpret)


def moe_step_bytes(num_active: float, d_model: int, d_ff: int,
                   dtype_bytes: int = 2, *, tokens: int = 0,
                   top_k: int = 0) -> float:
    """HBM bytes per MoE layer per decode step under XShare.

    Expert weights dominate in the decode regime (the paper's premise):
    3 * d * f per expert, fetched once per step for each *activated*
    expert; activations add 2*T*d + routed intermediate traffic.
    """
    w = num_active * 3 * d_model * d_ff * dtype_bytes
    act = tokens * d_model * dtype_bytes * (2 + 2 * top_k)
    return w + act


def dispatch_einsum_bytes(tokens: int, num_experts: int, capacity: int,
                          d_model: int, dtype_bytes: int = 4,
                          groups: int = 1) -> float:
    """Peak dispatch-intermediate footprint of the GShard einsum path:
    the (G, t, E, C) dispatch + combine one-hots and the (G, E, C, d)
    gathered/expert-output activations — all scale with E * C whether
    or not an expert is routed."""
    t = tokens // groups
    onehots = 2 * groups * t * num_experts * capacity * dtype_bytes
    expert_act = 2 * groups * num_experts * capacity * d_model * dtype_bytes
    return onehots + expert_act


def dispatch_sorted_bytes(tokens: int, top_k: int, num_experts: int,
                          d_model: int, dtype_bytes: int = 4,
                          block_t: int = 128,
                          max_active: Optional[int] = None) -> float:
    """Peak dispatch-intermediate footprint of the sorted grouped path:
    the (P, d) gathered rows + (P, d) expert outputs where
    P = T*k (+ tile padding per occupied expert), plus the (N,)-sized
    sort/offset vectors. Scales with routed pairs, not E * C.

    Weight traffic is intentionally excluded on both sides: the Pallas
    kernel streams weight tiles through VMEM (never materialized), and
    the einsum path reads each expert's weights once too. The CPU
    tile-gather fallback does materialize per-tile weight copies — the
    benchmark reports those separately (sorted_jnp_weight_gather_bytes)."""
    n = tokens * top_k
    occ = min(num_experts, n) if max_active is None else max_active
    p = n + occ * (block_t - 1)
    rows = 2 * p * d_model * dtype_bytes
    vecs = 5 * n * 4
    return rows + vecs
