"""Public jit'd wrappers around the Pallas kernels, plus byte-traffic
models used by the roofline analysis and OTPS modeling.

On this CPU container the kernels execute in interpret mode; on TPU
the same call sites compile natively (interpret=False).
"""
from __future__ import annotations

from typing import Optional

import jax.numpy as jnp

from repro.kernels.decode_attn import decode_attention
from repro.kernels.moe_ffn import moe_ffn
from repro.kernels.ssd_scan import ssd_scan

__all__ = ["xshare_moe_ffn", "flash_decode", "ssd_chunk_scan",
           "moe_step_bytes"]


def xshare_moe_ffn(x, w1, w3, w2, combine, active, *,
                   max_active: Optional[int] = None, block_f: int = 512,
                   interpret: bool = True):
    """Masked expert FFN; weight HBM traffic ~ max_active, not E."""
    E = w1.shape[0]
    ma = E if max_active is None else min(max_active, E)
    bf = block_f
    while w1.shape[2] % bf:
        bf //= 2
    return moe_ffn(x, w1, w3, w2, combine, active, max_active=ma,
                   block_f=bf, interpret=interpret)


def flash_decode(q, k, v, lengths, *, block_s: int = 512,
                 interpret: bool = True):
    return decode_attention(q, k, v, lengths, block_s=block_s,
                            interpret=interpret)


def ssd_chunk_scan(x, dt, A, Bm, Cm, *, chunk: int = 128, block_h: int = 8,
                   interpret: bool = True):
    bh = block_h
    while x.shape[2] % bh:
        bh //= 2
    return ssd_scan(x, dt, A, Bm, Cm, chunk=chunk, block_h=bh,
                    interpret=interpret)


def moe_step_bytes(num_active: float, d_model: int, d_ff: int,
                   dtype_bytes: int = 2, *, tokens: int = 0,
                   top_k: int = 0) -> float:
    """HBM bytes per MoE layer per decode step under XShare.

    Expert weights dominate in the decode regime (the paper's premise):
    3 * d * f per expert, fetched once per step for each *activated*
    expert; activations add 2*T*d + routed intermediate traffic.
    """
    w = num_active * 3 * d_model * d_ff * dtype_bytes
    act = tokens * d_model * dtype_bytes * (2 + 2 * top_k)
    return w + act
