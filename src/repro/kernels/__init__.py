"""Pallas TPU kernels for the perf-critical serving hot spots, validated
in interpret mode against the pure-jnp oracles in ref.py."""
from repro.kernels.ops import (  # noqa: F401
    xshare_moe_ffn, xshare_grouped_ffn, flash_decode, ssd_chunk_scan,
    moe_step_bytes, dispatch_einsum_bytes, dispatch_sorted_bytes,
)
from repro.kernels import ref  # noqa: F401
