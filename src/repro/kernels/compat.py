"""Pallas-TPU API compatibility across jax versions.

``pltpu.TPUCompilerParams`` was renamed to ``pltpu.CompilerParams`` in
newer jax releases; the fields we use (dimension_semantics, ...) are
identical. Kernels import the factory from here so they run on both.
"""
from __future__ import annotations

from jax.experimental.pallas import tpu as pltpu


def tpu_compiler_params(**kwargs):
    cls = getattr(pltpu, "CompilerParams", None) \
        or getattr(pltpu, "TPUCompilerParams")
    return cls(**kwargs)
