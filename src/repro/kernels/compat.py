"""Pallas-TPU API compatibility across jax versions.

``pltpu.TPUCompilerParams`` was renamed to ``pltpu.CompilerParams`` in
newer jax releases; the fields we use (dimension_semantics, ...) are
identical. Kernels import the factory from here so they run on both.

Also the single source of truth for interpret-vs-compile: every Pallas
entry point defaults ``interpret=None`` and resolves it here, so the
same call sites compile natively on TPU and fall back to the Python
interpreter everywhere else (CPU CI, tests). ``REPRO_PALLAS_INTERPRET``
overrides in either direction (=1 forces interpret on TPU for
debugging, =0 forces compilation off-TPU, e.g. under Pallas' Triton /
Mosaic-GPU lowerings).
"""
from __future__ import annotations

import os
from typing import Optional

import jax
from jax.experimental.pallas import tpu as pltpu

_ENV = "REPRO_PALLAS_INTERPRET"


def tpu_compiler_params(**kwargs):
    cls = getattr(pltpu, "CompilerParams", None) \
        or getattr(pltpu, "TPUCompilerParams")
    return cls(**kwargs)


def resolve_interpret(interpret: Optional[bool] = None) -> bool:
    """Explicit argument > env override > backend auto-detect."""
    if interpret is not None:
        return bool(interpret)
    env = os.environ.get(_ENV)
    if env is not None and env != "":
        return env.lower() not in ("0", "false", "no")
    return jax.default_backend() != "tpu"
