"""Pallas TPU kernel: Mamba2 chunked SSD scan.

Grid: (batch, head tiles, seq chunks) — the chunk axis is sequential
("arbitrary") and the running inter-chunk SSM state lives in VMEM
scratch, so the HBM traffic per chunk is just the chunk's activations:
the TPU adaptation of Mamba2's fused CUDA scan (intra-chunk work is
matmul-shaped for the MXU; the recurrence only crosses chunk
boundaries). Emits both the per-position outputs and the final state
(decode handoff).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.compat import resolve_interpret, tpu_compiler_params


def _kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, y_ref, st_ref, state_scr, *,
            num_chunks: int, chunk: int):
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        state_scr[...] = jnp.zeros_like(state_scr)

    x = x_ref[0].astype(jnp.float32)        # (l, bh, hd)
    dt = dt_ref[0].astype(jnp.float32)      # (l, bh)
    A = a_ref[...].astype(jnp.float32)      # (bh,)
    Bm = b_ref[0].astype(jnp.float32)       # (l, bh, ds)
    Cm = c_ref[0].astype(jnp.float32)       # (l, bh, ds)

    dA = dt * A[None, :]                    # (l, bh) <= 0
    cum = jnp.cumsum(dA, axis=0)            # (l, bh)

    # intra-chunk
    seg = cum[:, None, :] - cum[None, :, :]                 # (i, j, bh)
    tri = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0) >= \
        jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    decay = jnp.where(tri[:, :, None], jnp.exp(seg), 0.0)
    scores = jnp.einsum("ihs,jhs->ijh", Cm, Bm)
    M = scores * decay * dt[None, :, :]                     # fold dt_j
    y = jnp.einsum("ijh,jhp->ihp", M, x)

    # inter-chunk contribution from the carried state
    y += jnp.einsum("ihs,hps,ih->ihp", Cm, state_scr[...], jnp.exp(cum))

    # state update
    decay_states = jnp.exp(cum[-1:, :] - cum)               # (l, bh)
    upd = jnp.einsum("lhs,lh,lhp->hps", Bm, decay_states * dt, x)
    state_scr[...] = state_scr[...] * jnp.exp(cum[-1])[:, None, None] + upd

    y_ref[0] = y.astype(y_ref.dtype)

    @pl.when(j == num_chunks - 1)
    def _emit_state():
        st_ref[0] = state_scr[...].astype(st_ref.dtype)


@functools.partial(jax.jit, static_argnames=("chunk", "block_h",
                                             "interpret"))
def ssd_scan(x: jnp.ndarray, dt: jnp.ndarray, A: jnp.ndarray,
             Bm: jnp.ndarray, Cm: jnp.ndarray, *, chunk: int = 128,
             block_h: int = 8, interpret=None):
    """x: (B,S,nh,hd); dt: (B,S,nh); A: (nh,); Bm/Cm: (B,S,nh,ds)
    (heads pre-broadcast). Returns (y (B,S,nh,hd), state (B,nh,hd,ds)).
    S must pad to a chunk multiple (dt padding 0 => exp(0)=1 decay,
    zero input: harmless)."""
    interpret = resolve_interpret(interpret)
    B, S, nh, hd = x.shape
    ds = Bm.shape[-1]
    l = min(chunk, S)
    Sp = ((S + l - 1) // l) * l
    if Sp != S:
        pad = ((0, 0), (0, Sp - S), (0, 0))
        x = jnp.pad(x, pad + ((0, 0),))
        dt = jnp.pad(dt, pad)
        Bm = jnp.pad(Bm, pad + ((0, 0),))
        Cm = jnp.pad(Cm, pad + ((0, 0),))
    nc = Sp // l
    bh = min(block_h, nh)
    assert nh % bh == 0
    nh_t = nh // bh

    y, st = pl.pallas_call(
        functools.partial(_kernel, num_chunks=nc, chunk=l),
        grid=(B, nh_t, nc),
        in_specs=[
            pl.BlockSpec((1, l, bh, hd), lambda b, h, j: (b, j, h, 0)),
            pl.BlockSpec((1, l, bh), lambda b, h, j: (b, j, h)),
            pl.BlockSpec((bh,), lambda b, h, j: (h,)),
            pl.BlockSpec((1, l, bh, ds), lambda b, h, j: (b, j, h, 0)),
            pl.BlockSpec((1, l, bh, ds), lambda b, h, j: (b, j, h, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, l, bh, hd), lambda b, h, j: (b, j, h, 0)),
            pl.BlockSpec((1, bh, hd, ds), lambda b, h, j: (b, h, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, Sp, nh, hd), x.dtype),
            jax.ShapeDtypeStruct((B, nh, hd, ds), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((bh, hd, ds), jnp.float32)],
        interpret=interpret,
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
    )(x, dt, A, Bm, Cm)
    return y[:, :S], st
