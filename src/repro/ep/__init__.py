"""Expert-parallel execution layer: histogram-driven placement with
hot-expert replication (placement.py) and the real shard_map sorted-
dispatch path with ragged all-to-all row exchange (executor.py).

``ep_context`` binds an EPExecutor for model code: ``expert_ffn``'s
``dispatch="ep"`` mode routes through the bound executor, and degrades
to the bit-identical single-device sorted path when none is bound.
"""
from __future__ import annotations

import contextlib

from repro.ep.placement import (Placement, contiguous_placement,
                                placement_peak, plan_placement, rebalance)
from repro.ep.executor import EPExecutor, EPStats, exchange_counts

_STATE = {"executor": None}


@contextlib.contextmanager
def ep_context(executor: EPExecutor):
    """Bind an EPExecutor for ``expert_ffn(dispatch="ep")`` callers."""
    old = _STATE["executor"]
    _STATE["executor"] = executor
    try:
        yield executor
    finally:
        _STATE["executor"] = old


def current_executor():
    return _STATE["executor"]


__all__ = [
    "EPExecutor", "EPStats", "Placement", "contiguous_placement",
    "current_executor", "ep_context", "exchange_counts", "placement_peak",
    "plan_placement", "rebalance",
]
