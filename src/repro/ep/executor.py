"""Real expert-parallel execution of sorted dispatch under shard_map.

This replaces the tile-axis ``with_sharding_constraint`` approximation:
token rows are *actually exchanged* between shards. Per shard, per MoE
layer:

  1. route pairs to shards — each (token, expert) pair's destination is
     ``placement.hosts[e, token % nhosts[e]]``: the shard hosting the
     expert, with a replicated expert's rows split deterministically
     across its replicas (token-id modulus, so routing is reproducible
     and independent of shard count);
  2. per-shard argsort by destination + segment offsets (the same
     bincount/cumsum machinery as ``dispatch_plan``);
  3. ragged all-to-all — the (S,) send-count vector is exchanged first
     (one int per peer), then the payload, packed into per-peer
     segments padded to ``max_rows`` — the per-round maximum, NOT the
     GShard capacity E/G*C. Only occupied rows carry data; padding is
     zeros and expert-id -1;
  4. grouped GEMM over the received rows with the shard's *local*
     expert weights — the existing sorted pipeline verbatim
     (``dispatch_plan`` + ``gather_tokens`` + Pallas ``grouped_ffn`` on
     TPU / tile-gather einsum elsewhere + ``combine_scatter``), built
     over local expert slots so per-shard weight memory is
     ``placement.expert_cap`` experts, not E;
  5. reverse all-to-all ships each row's FFN output back to its source
     shard, which scatter-combines with the gate weights in
     expert-sorted pair order — the *same summation order* as the
     single-device sorted reference, so the EP path is numerically
     exact against it.

``max_rows`` sizing: the worst case (every local pair to one peer) is
always exact; ``max_rows="auto"`` runs the counts-only exchange first
and buckets the observed per-peer maximum to a power of two, so the
payload is padded to the per-round max while recompiles stay bounded
(one compile per bucket). A count above ``max_rows`` clamps with
capacity semantics (first tokens kept, surplus pairs dropped with zero
gate weight).
"""
from __future__ import annotations

from typing import Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.ep.placement import (Placement, contiguous_placement,
                                placement_peak, plan_placement, rebalance)
from repro.models.dispatch import (combine_scatter, default_block_t,
                                   dispatch_plan, gather_tokens)
from repro.sharding import get_shard_map


class EPStats(NamedTuple):
    """Measured per-layer EP execution profile (host numpy).

    computed_rows[s] — real token-assignment rows shard s ran through
    its grouped GEMM (segment sizes, no tile padding). The max over
    shards is the bottleneck-device metric the paper's "peak GPU load"
    claim is about.
    tile_rows[s]     — rows shard s's grouped GEMM actually executed:
                       occupied tiles * block_t, i.e. segments rounded
                       up to the tile grid. At decode sizes (segments
                       of a few rows) this is dominated by the number
                       of *active experts* on the shard — the quantity
                       Algorithm 6 bounds — so it is the measured
                       per-device cost the EP scoreboard compares.
    sent_rows[s]     — rows shard s shipped to *other* shards.
    a2a_bytes[s]     — bytes shard s put on the interconnect: payload
                       forward + reverse, expert ids forward, and the
                       count vectors both ways.
    count_matrix     — (S, S) rows, [src, dst] routed rows.
    max_rows         — the per-peer payload padding this round used.
    """
    computed_rows: np.ndarray
    tile_rows: np.ndarray
    sent_rows: np.ndarray
    a2a_bytes: np.ndarray
    count_matrix: np.ndarray
    max_rows: int

    @property
    def peak_rows(self) -> int:
        return int(self.computed_rows.max())

    @property
    def peak_tile_rows(self) -> int:
        return int(self.tile_rows.max())

    @property
    def total_a2a_bytes(self) -> int:
        return int(self.a2a_bytes.sum())


def _route_pairs(idx, w, hosts, nhosts, tok0, num_experts, num_shards):
    """Flatten (T_loc, k) routing to pairs and pick each pair's
    destination shard (sentinel S for dead pairs)."""
    T_loc, k = idx.shape
    N = T_loc * k
    e = idx.reshape(N).astype(jnp.int32)
    wf = w.reshape(N).astype(jnp.float32)
    tokl = jnp.arange(N, dtype=jnp.int32) // k
    live = (e >= 0) & (e < num_experts) & (wf != 0.0)
    ec = jnp.clip(e, 0, num_experts - 1)
    gtok = tok0 + tokl
    nrep = jnp.maximum(nhosts[ec], 1)
    dest = jnp.where(live, hosts[ec, gtok % nrep], num_shards)
    return ec, wf, tokl, live, dest


def _build_counts_fn(mesh, axis: str, num_experts: int, num_shards: int):
    S = num_shards

    def body(idx, w, hosts, nhosts):
        rix = jax.lax.axis_index(axis)
        T_loc = idx.shape[0]
        _, _, _, live, dest = _route_pairs(
            idx, w, hosts, nhosts, rix * T_loc, num_experts, S)
        counts = jnp.zeros((S,), jnp.int32).at[dest].add(
            live.astype(jnp.int32), mode="drop")
        return counts[None]

    sm = get_shard_map()
    return jax.jit(sm(body, mesh=mesh,
                      in_specs=(P(axis), P(axis), P(), P()),
                      out_specs=P(axis)))


def exchange_counts(idx, w, placement: Placement, *, mesh,
                    axis: str = "model") -> np.ndarray:
    """The counts phase alone: (S, S) matrix of rows each shard would
    send each peer this round. Drives ``max_rows="auto"`` payload
    sizing and placement-quality probes without moving any rows."""
    S = placement.num_shards
    T = idx.shape[0]
    pad = (-T) % S
    if pad:
        idx = jnp.concatenate(
            [idx, jnp.full((pad, idx.shape[1]), -1, idx.dtype)])
        w = jnp.concatenate([w, jnp.zeros((pad, w.shape[1]), w.dtype)])
    fn = _build_counts_fn(mesh, axis, placement.num_experts, S)
    out = fn(idx, w, jnp.asarray(placement.hosts),
             jnp.asarray(placement.nhosts))
    return np.asarray(out)


def _build_ep_fn(mesh, axis: str, *, num_shards: int, num_experts: int,
                 expert_cap: int, max_rows: int, block_t: int,
                 block_f: int, use_kernel: bool):
    """The jitted shard_map EP layer for one static configuration.

    Traced arguments: x (T, d), idx/w (T, k), the FULL expert weights
    (E, d, f)x3 (gathered into per-shard (S, cap, d, f) slices inside
    the trace from ``local_eids``, so a placement change is a new
    gather, not a new compile), and the placement lookup tables.
    """
    S, E, cap, M, bt = num_shards, num_experts, expert_cap, max_rows, \
        block_t

    def body(x, idx, w, hosts, nhosts, lslot, w1s, w3s, w2s):
        rix = jax.lax.axis_index(axis)
        T_loc, d = x.shape
        k = idx.shape[1]
        N = T_loc * k
        ec, wf, tokl, live, dest = _route_pairs(
            idx, w, hosts, nhosts, rix * T_loc, E, S)
        # --- per-shard sort by destination + segment offsets ----------
        order = jnp.argsort(dest)                 # stable: token order
        s_dest = dest[order]
        s_e = ec[order]
        s_live = live[order]
        send_counts = jnp.zeros((S,), jnp.int32).at[dest].add(
            live.astype(jnp.int32), mode="drop")
        start = jnp.cumsum(send_counts) - send_counts
        dclip = jnp.clip(s_dest, 0, S - 1)
        rank = jnp.arange(N, dtype=jnp.int32) - start[dclip]
        kept = s_live & (rank < M)                # M-overflow: capacity
        pos = jnp.where(kept, dclip * M + rank, S * M)  # drop semantics
        xbuf = jnp.zeros((S * M, d), x.dtype).at[pos].set(
            x[tokl[order]], mode="drop")
        ebuf = jnp.full((S * M,), -1, jnp.int32).at[pos].set(
            s_e, mode="drop")
        # --- ragged all-to-all: counts first, then padded payload -----
        recv_counts = jax.lax.all_to_all(send_counts, axis, 0, 0,
                                         tiled=True)
        recv_x = jax.lax.all_to_all(xbuf.reshape(S, M, d), axis, 0, 0,
                                    tiled=True).reshape(S * M, d)
        recv_e = jax.lax.all_to_all(ebuf.reshape(S, M), axis, 0, 0,
                                    tiled=True).reshape(S * M)
        # --- grouped GEMM over received rows, local expert slots ------
        lsl = jnp.where(recv_e >= 0,
                        lslot[0, jnp.clip(recv_e, 0, E - 1)], -1)
        plan = dispatch_plan(lsl[:, None],
                             (lsl >= 0).astype(jnp.float32)[:, None],
                             cap, block_t=bt, pad_shards=1)
        xs = gather_tokens(recv_x, plan)
        from repro.kernels.moe_ffn import grouped_ffn_apply
        ys = grouped_ffn_apply(xs, w1s[0], w3s[0], w2s[0], plan,
                               use_kernel=use_kernel, block_f=block_f)
        rows_out = combine_scatter(ys, plan, S * M, jnp.float32)
        # --- reverse exchange + source-side combine -------------------
        back = jax.lax.all_to_all(rows_out.reshape(S, M, d), axis, 0, 0,
                                  tiled=True).reshape(S * M, d)
        val_sorted = jnp.where(kept[:, None],
                               back[jnp.minimum(pos, S * M - 1)], 0.0)
        val = jnp.zeros((N, d), jnp.float32).at[order].set(val_sorted)
        keptf = jnp.zeros((N,), bool).at[order].set(kept)
        # combine in expert-sorted pair order — the exact summation
        # order of the single-device combine_scatter
        eorder = jnp.argsort(jnp.where(live, ec, E))
        contrib = (jnp.where(keptf, wf, 0.0)[:, None] * val)[eorder]
        y = jnp.zeros((T_loc, d), jnp.float32).at[tokl[eorder]].add(
            contrib)
        # --- measured profile -----------------------------------------
        off = (jnp.arange(S) != rix).astype(jnp.int32)
        sent = (send_counts * off).sum()
        recv_off = (recv_counts * off).sum()
        itm = x.dtype.itemsize
        a2a = (sent + recv_off) * d * itm \
            + sent * 4 + 2 * S * 4          # payloads + eids + counts
        tile_rows = plan.tile_valid.sum() * bt
        return (y.astype(x.dtype), recv_counts.sum()[None],
                tile_rows[None], sent[None], a2a[None], send_counts[None])

    sm = get_shard_map()
    mapped = sm(body, mesh=mesh,
                in_specs=(P(axis), P(axis), P(axis), P(), P(), P(axis),
                          P(axis), P(axis), P(axis)),
                out_specs=(P(axis), P(axis), P(axis), P(axis), P(axis),
                           P(axis)))

    def run(x, idx, w, w1, w3, w2, hosts, nhosts, local_eids, local_slot):
        w1s = jnp.take(w1, jnp.clip(local_eids, 0, E - 1), axis=0)
        w3s = jnp.take(w3, jnp.clip(local_eids, 0, E - 1), axis=0)
        w2s = jnp.take(w2, jnp.clip(local_eids, 0, E - 1), axis=0)
        return mapped(x, idx, w, hosts, nhosts, local_slot,
                      w1s, w3s, w2s)

    return jax.jit(run)


def _pow2_bucket(n: int, lo: int = 8) -> int:
    b = lo
    while b < n:
        b *= 2
    return b


class EPExecutor:
    """Driver for the shard_map EP layer: owns a mesh + placement,
    caches compiled variants per (shape, max_rows) configuration, and
    rebalances placement between batches with hysteresis.

    ``__call__`` returns (y, EPStats); ``ffn`` returns y alone (the
    ``expert_ffn(dispatch="ep")`` entry — safe inside an outer jit
    because it never syncs the stats).
    """

    def __init__(self, mesh, placement: Placement, *, axis: str = "model",
                 block_t: Optional[int] = None, block_f: int = 512,
                 use_kernel: Optional[bool] = None,
                 max_rows=None,
                 replicate_hot: int = 0,
                 max_replicas: Optional[int] = None,
                 hysteresis: float = 0.1):
        from repro.kernels.compat import resolve_interpret
        axis_sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
        if axis_sizes.get(axis) != placement.num_shards:
            raise ValueError(
                f"mesh axis {axis!r} has size {axis_sizes.get(axis)}, "
                f"placement expects {placement.num_shards} shards")
        self.mesh, self.axis = mesh, axis
        self.placement = placement
        self.block_t = block_t
        self.block_f = block_f
        self.use_kernel = (not resolve_interpret(None)) \
            if use_kernel is None else bool(use_kernel)
        self.max_rows = max_rows
        self.replicate_hot = replicate_hot
        self.max_replicas = max_replicas
        self.hysteresis = hysteresis
        self.rebalances = 0
        self.rebalances_skipped = 0
        self._fns: Dict[tuple, object] = {}

    @classmethod
    def from_config(cls, ep_cfg, num_experts: int, *, mesh=None,
                    load: Optional[np.ndarray] = None,
                    axis: str = "model") -> "EPExecutor":
        """Build an executor from ``configs.base.EPConfig``: makes the
        mesh (``sharding.make_ep_mesh``) unless one is passed, and
        plans the initial placement from ``load`` (gate-histogram
        priors) when given, contiguous otherwise."""
        if mesh is None:
            from repro.sharding import make_ep_mesh
            mesh = make_ep_mesh(ep_cfg.num_shards, axis=axis)
        if load is None:
            pl = contiguous_placement(num_experts, ep_cfg.num_shards)
            if ep_cfg.replicate_hot:
                pl = plan_placement(np.ones(num_experts),
                                    ep_cfg.num_shards,
                                    replicate_hot=ep_cfg.replicate_hot,
                                    max_replicas=ep_cfg.max_replicas)
        else:
            pl = plan_placement(np.asarray(load, np.float64),
                                ep_cfg.num_shards,
                                replicate_hot=ep_cfg.replicate_hot,
                                max_replicas=ep_cfg.max_replicas)
        return cls(mesh, pl, axis=axis, block_t=ep_cfg.block_t,
                   max_rows=ep_cfg.max_rows,
                   replicate_hot=ep_cfg.replicate_hot,
                   max_replicas=ep_cfg.max_replicas,
                   hysteresis=ep_cfg.rebalance_hysteresis)

    # -------------------------------------------------- placement ----

    def update_placement(self, load: np.ndarray) -> bool:
        """Between-batch rebalance from fresh load predictions (e.g.
        ``Scheduler.gate_priors().sum(0)``). Hysteresis means most
        calls are no-ops; a True return implies new weight gathers on
        the next layer call (a recompile only if expert_cap or the
        replica width changed)."""
        new, changed = rebalance(self.placement, load,
                                 replicate_hot=self.replicate_hot,
                                 max_replicas=self.max_replicas,
                                 hysteresis=self.hysteresis)
        if changed:
            self.placement = new
            self.rebalances += 1
        else:
            self.rebalances_skipped += 1
        return changed

    def predicted_peak(self, load: np.ndarray) -> float:
        return placement_peak(self.placement, load)

    # -------------------------------------------------- execution ----

    def _resolve_max_rows(self, idx, w, max_rows, n_loc: int) -> int:
        mr = self.max_rows if max_rows is None else max_rows
        if mr is None:
            return n_loc                      # worst case, always exact
        if mr == "auto":
            counts = exchange_counts(idx, w, self.placement,
                                     mesh=self.mesh, axis=self.axis)
            return min(n_loc, _pow2_bucket(max(1, int(counts.max()))))
        return min(n_loc, int(mr))

    def _fn(self, key):
        if key not in self._fns:
            (M, bt) = key[-2:]
            self._fns[key] = _build_ep_fn(
                self.mesh, self.axis, num_shards=self.placement.num_shards,
                num_experts=self.placement.num_experts,
                expert_cap=self.placement.expert_cap, max_rows=M,
                block_t=bt, block_f=self.block_f,
                use_kernel=self.use_kernel)
        return self._fns[key]

    def __call__(self, x, w1, w3, w2, idx, w, *,
                 max_rows=None) -> Tuple[jnp.ndarray, EPStats]:
        pl = self.placement
        S = pl.num_shards
        T, d = x.shape
        k = idx.shape[1]
        pad = (-T) % S
        if pad:
            x = jnp.concatenate([x, jnp.zeros((pad, d), x.dtype)])
            idx = jnp.concatenate(
                [idx, jnp.full((pad, k), -1, idx.dtype)])
            w = jnp.concatenate([w, jnp.zeros((pad, k), w.dtype)])
        n_loc = (T + pad) // S * k
        M = self._resolve_max_rows(idx, w, max_rows, n_loc)
        bt = self.block_t or default_block_t(S * M, pl.expert_cap)
        key = (T + pad, k, d, w1.shape[-1], pl.expert_cap,
               pl.hosts.shape[1], M, bt)
        fn = self._fn(key)
        y, rows, trows, sent, bytes_, cmat = fn(
            x, idx, w, w1, w3, w2, jnp.asarray(pl.hosts),
            jnp.asarray(pl.nhosts), jnp.asarray(pl.local_eids),
            jnp.asarray(pl.local_slot))
        stats = EPStats(computed_rows=np.asarray(rows),
                        tile_rows=np.asarray(trows),
                        sent_rows=np.asarray(sent),
                        a2a_bytes=np.asarray(bytes_),
                        count_matrix=np.asarray(cmat),
                        max_rows=M)
        return y[:T], stats

    def ffn(self, x, w1, w3, w2, idx, w) -> jnp.ndarray:
        """y alone, no host sync — usable inside an outer jit (the
        ``dispatch="ep"`` model path). max_rows resolves statically
        (never "auto": that needs a host round-trip)."""
        pl = self.placement
        S = pl.num_shards
        T, d = x.shape
        k = idx.shape[1]
        pad = (-T) % S
        if pad:
            x = jnp.concatenate([x, jnp.zeros((pad, d), x.dtype)])
            idx = jnp.concatenate(
                [idx, jnp.full((pad, k), -1, idx.dtype)])
            w = jnp.concatenate([w, jnp.zeros((pad, k), w.dtype)])
        n_loc = (T + pad) // S * k
        mr = self.max_rows
        M = n_loc if (mr is None or mr == "auto") else min(n_loc, int(mr))
        bt = self.block_t or default_block_t(S * M, pl.expert_cap)
        key = (T + pad, k, d, w1.shape[-1], pl.expert_cap,
               pl.hosts.shape[1], M, bt)
        y = self._fn(key)(
            x, idx, w, w1, w3, w2, jnp.asarray(pl.hosts),
            jnp.asarray(pl.nhosts), jnp.asarray(pl.local_eids),
            jnp.asarray(pl.local_slot))[0]
        return y[:T]
