"""Dynamic expert-to-shard placement from gate histograms.

The EP bottleneck is the busiest shard: under sorted dispatch a shard
computes exactly the token segments of the experts it hosts, so peak
load is a pure function of (routing skew x placement). This module
consumes the per-expert load predictions the serving layer already
collects (``Scheduler.gate_priors()`` — the same priors feeding
Algorithm 4) and turns them into a placement:

  * assignment   — greedy LPT: experts in decreasing predicted load,
                   each to the currently least-loaded shard. Classic
                   4/3-approximation of makespan; deterministic
                   tie-breaking (expert id, then shard id) keeps
                   routing reproducible across hosts.
  * replication  — the hottest experts are copied onto extra shards and
                   their rows split deterministically across replicas
                   (token_id mod num_replicas — see executor.py), the
                   core idea of "Fast MoE Inference via Predictive
                   Prefetching and Expert Replication" (arxiv
                   2605.11537). A replica costs weight memory, not
                   accuracy: every replica holds identical weights.
  * rebalancing  — ``rebalance`` only adopts a new placement when its
                   predicted peak beats the incumbent's by more than a
                   hysteresis margin, so placement (and the weight
                   re-shard it implies) never churns between batches
                   with statistically identical traffic.

Everything here is host-side numpy: placement changes happen between
batches, never inside a jitted step.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Optional, Tuple

import numpy as np


@dataclass(frozen=True)
class Placement:
    """Expert-to-shard map with replication.

    hosts[e]   — shard ids hosting expert e, primary first, padded by
                 cycling (padding is never indexed: the executor picks
                 ``hosts[e, token % nhosts[e]]``).
    nhosts[e]  — number of distinct hosts of e (>= 1).
    local_eids — (S, cap) global expert ids resident on each shard,
                 -1 padding; the executor gathers weight slices with it.
    local_slot — (S, E) local slot of expert e on shard s, -1 if absent.
    """
    num_experts: int
    num_shards: int
    hosts: np.ndarray        # (E, R_max) int32
    nhosts: np.ndarray       # (E,) int32
    local_eids: np.ndarray   # (S, cap) int32
    local_slot: np.ndarray   # (S, E) int32
    version: int = 0

    @property
    def expert_cap(self) -> int:
        return self.local_eids.shape[1]

    @property
    def replication_factor(self) -> float:
        """Mean replicas per expert (1.0 = no replication)."""
        return float(self.nhosts.mean())

    def weight_bytes_factor(self) -> float:
        """Per-shard weight memory vs an even non-replicated split:
        cap / ceil(E/S)."""
        even = -(-self.num_experts // self.num_shards)
        return self.expert_cap / even


def _tables(host_sets, E: int, S: int, version: int) -> Placement:
    """Freeze per-expert host lists into the dense lookup tables."""
    nhosts = np.array([len(h) for h in host_sets], np.int32)
    r_max = int(nhosts.max()) if E else 1
    hosts = np.zeros((E, r_max), np.int32)
    for e, hs in enumerate(host_sets):
        for j in range(r_max):
            hosts[e, j] = hs[j % len(hs)]
    per_shard = [[] for _ in range(S)]
    for e, hs in enumerate(host_sets):
        for s in hs:
            per_shard[s].append(e)
    cap = max(1, max(len(v) for v in per_shard))
    local_eids = np.full((S, cap), -1, np.int32)
    local_slot = np.full((S, E), -1, np.int32)
    for s, eids in enumerate(per_shard):
        for j, e in enumerate(eids):
            local_eids[s, j] = e
            local_slot[s, e] = j
    return Placement(num_experts=E, num_shards=S, hosts=hosts,
                     nhosts=nhosts, local_eids=local_eids,
                     local_slot=local_slot, version=version)


def contiguous_placement(num_experts: int, num_shards: int) -> Placement:
    """The static baseline layout: expert e on shard e // ceil(E/S) —
    exactly how the expert axis shards contiguously over the mesh
    "model" axis (last shard smaller when E % S != 0)."""
    per = -(-num_experts // num_shards)
    host_sets = [[min(e // per, num_shards - 1)] for e in range(num_experts)]
    return _tables(host_sets, num_experts, num_shards, version=0)


def plan_placement(load: np.ndarray, num_shards: int, *,
                   replicate_hot: int = 0,
                   max_replicas: Optional[int] = None,
                   version: int = 0) -> Placement:
    """Assign experts to shards minimizing predicted peak load.

    load: (E,) predicted per-expert load (gate-histogram mass or
    measured segment sizes — only ratios matter). replicate_hot: the
    top-``replicate_hot`` experts by load are replicated onto
    ``max_replicas`` shards (default: all of them), splitting their
    rows ~evenly across replicas.

    Deterministic: ties in load break by expert id; ties in shard load
    break by shard id. Same inputs => identical placement on every host.
    """
    load = np.asarray(load, np.float64)
    E = load.shape[0]
    S = num_shards
    r = S if max_replicas is None else max(1, min(max_replicas, S))
    hot = set()
    if replicate_hot > 0 and E:
        # stable: by (-load, expert id)
        order = np.lexsort((np.arange(E), -load))
        hot = set(int(e) for e in order[:min(replicate_hot, E)])
    # LPT over *effective* loads: a replicated expert contributes
    # load/r to each of its r hosts
    order = np.lexsort((np.arange(E), -load))
    shard_load = np.zeros(S, np.float64)
    host_sets = [None] * E
    for e in order:
        e = int(e)
        if e in hot:
            # replicas on the r least-loaded shards (ids break ties)
            picks = np.lexsort((np.arange(S), shard_load))[:r]
            picks = sorted(int(s) for s in picks)
            for s in picks:
                shard_load[s] += load[e] / len(picks)
            host_sets[e] = picks
        else:
            s = int(np.lexsort((np.arange(S), shard_load))[0])
            shard_load[s] += load[e]
            host_sets[e] = [s]
    return _tables(host_sets, E, S, version=version)


def placement_peak(placement: Placement, load: np.ndarray) -> float:
    """Predicted peak per-shard load under a placement: each expert
    contributes load/nhosts to every host (the executor splits rows
    across replicas ~evenly)."""
    load = np.asarray(load, np.float64)
    shard = np.zeros(placement.num_shards, np.float64)
    for e in range(placement.num_experts):
        n = int(placement.nhosts[e])
        for j in range(n):
            shard[int(placement.hosts[e, j])] += load[e] / n
    return float(shard.max()) if len(shard) else 0.0


def rebalance(prev: Placement, load: np.ndarray, *,
              replicate_hot: int = 0,
              max_replicas: Optional[int] = None,
              hysteresis: float = 0.1) -> Tuple[Placement, bool]:
    """Between-batch rebalancing with hysteresis.

    Returns (placement, changed). The candidate placement is adopted
    only when its predicted peak improves on the incumbent's by more
    than ``hysteresis`` (relative), so statistically identical traffic
    never causes a weight re-shard — placement churn must never stall
    decode.
    """
    cand = plan_placement(load, prev.num_shards,
                          replicate_hot=replicate_hot,
                          max_replicas=max_replicas,
                          version=prev.version + 1)
    p_prev = placement_peak(prev, load)
    p_cand = placement_peak(cand, load)
    if p_cand < p_prev * (1.0 - hysteresis):
        return cand, True
    return prev, False
