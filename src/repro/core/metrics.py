"""Expert-activation metrics — the quantities the paper's tables report."""
from __future__ import annotations

import jax.numpy as jnp


def activated_experts(combine: jnp.ndarray) -> jnp.ndarray:
    """|union of experts any token routed to| for one layer.

    combine: (T, E) combine/weight matrix (zero == not routed).
    """
    return (jnp.abs(combine) > 0).any(axis=0).sum()


def activated_mask(combine: jnp.ndarray) -> jnp.ndarray:
    return (jnp.abs(combine) > 0).any(axis=0)


def per_group_load(active: jnp.ndarray, num_groups: int) -> jnp.ndarray:
    """Per device-group activated-expert counts (contiguous partition).

    Groups are ceil(E/G) experts wide; when E % G != 0 the trailing
    group(s) are narrower (zero-padded), matching ``ep_select`` and the
    EP placement baseline."""
    E = active.shape[-1]
    per = -(-E // num_groups)
    padded = jnp.pad(active.astype(jnp.int32),
                     [(0, 0)] * (active.ndim - 1)
                     + [(0, num_groups * per - E)])
    return padded.reshape(active.shape[:-1] + (num_groups, per)).sum(axis=-1)


def max_group_load(active: jnp.ndarray, num_groups: int) -> jnp.ndarray:
    """MaxLoad(S) — the paper's bottleneck-GPU metric (Sec 5.1)."""
    return per_group_load(active, num_groups).max()


def gate_mass_captured(gates: jnp.ndarray, mask: jnp.ndarray) -> jnp.ndarray:
    """Fraction of total gating probability mass inside the selected set —
    the modular proxy objective f(S), normalized."""
    total = gates.sum()
    kept = jnp.where(mask[None, :], gates, 0.0).sum()
    return kept / jnp.maximum(total, 1e-30)


def expected_activated(num_experts: int, top_k: int, batch: int) -> float:
    """Closed-form E[N_a] = N(1-(1-k/N)^B) from the introduction."""
    return num_experts * (1.0 - (1.0 - top_k / num_experts) ** batch)


def topk_overlap(idx_a: jnp.ndarray, idx_b: jnp.ndarray,
                 num_experts: int) -> jnp.ndarray:
    """|TopK(a) ∩ TopK(b)| — Fig 3's overlap statistic.

    idx_a, idx_b: (..., k) expert indices.
    """
    import jax
    a = jax.nn.one_hot(idx_a, num_experts, dtype=bool).any(axis=-2)
    b = jax.nn.one_hot(idx_b, num_experts, dtype=bool).any(axis=-2)
    return (a & b).sum(axis=-1)
