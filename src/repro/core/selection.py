"""XShare batch-aware expert selection — Algorithms 1-6 of the paper.

All functions are pure jnp with static shapes (budgets are Python ints),
so they jit/pjit cleanly inside a model forward pass. Expert sets are
represented as boolean masks over the expert axis; "selecting top-m"
with m == 0 degenerates to the warm-up set alone, matching the paper's
(m=0, k0>=1) configurations.

Scores: the paper aggregates the router's full gating vector
G_i = softmax(W_g x_i) over the batch (Sec 3.1). Callers pass those
full (pre-top-k) probabilities, shape (..., num_experts).
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

_BIG = 1e9  # priority bonus that dominates any sum of probabilities


def topk_mask(scores: jnp.ndarray, k: int) -> jnp.ndarray:
    """Boolean mask of the top-k entries along the last axis.

    k == 0 returns an all-False mask. Ties are broken by index
    (jax.lax.top_k is deterministic), matching a stable argsort.
    """
    E = scores.shape[-1]
    if k <= 0:
        return jnp.zeros(scores.shape, dtype=bool)
    k = min(k, E)
    _, idx = jax.lax.top_k(scores, k)          # (..., k)
    return jax.nn.one_hot(idx, E, dtype=bool).any(axis=-2)  # (..., E)


def warmup_union(gates: jnp.ndarray, k0: int) -> jnp.ndarray:
    """S0 = union over tokens of each token's top-k0 experts.

    gates: (..., T, E) -> mask (..., E). Tokens whose gate row is
    entirely zero (compute-masked continuous-batching slots) contribute
    no warm-up experts.
    """
    if k0 <= 0:
        return jnp.zeros(gates.shape[:-2] + gates.shape[-1:], dtype=bool)
    per_token = topk_mask(gates, k0)          # (..., T, E)
    per_token = per_token & (gates.sum(-1, keepdims=True) > 0)
    return per_token.any(axis=-2)             # (..., E)


def greedy_select(gates: jnp.ndarray, m: int,
                  warmup: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    """Algorithm 1 — GreedySelect.

    The proxy objective f(S) = sum_{j in S} sum_i g_ij is modular
    (Prop 3.2), so greedy == sorting experts by aggregated gating score
    and taking the top-m among experts not already in the warm-up set
    (Cor 3.3). Returns warmup | top_m(aggregated, E \\ warmup).

    gates: (T, E); warmup: (E,) bool or None; m: experts added beyond S0.
    """
    E = gates.shape[-1]
    agg = gates.sum(axis=0)                   # (E,) batch-aggregated utility
    if warmup is None:
        warmup = jnp.zeros((E,), dtype=bool)
    if m <= 0:
        return warmup
    # Exclude warm-up members from the greedy pool; if fewer than m
    # non-warm-up experts exist, top_k re-picks warm-up entries, which
    # the union makes a no-op.
    pool = jnp.where(warmup, -jnp.inf, agg)
    return warmup | topk_mask(pool, min(m, E))


def batch_select(gates: jnp.ndarray, m_l: int, k0: int) -> jnp.ndarray:
    """Algorithm 2 (selection phase) — warm-up + batch-level greedy.

    gates: (T, E) full router probabilities for every token in the batch.
    Returns the per-layer expert mask S_l, shape (E,).
    """
    s0 = warmup_union(gates, k0)
    return greedy_select(gates, m_l, s0)


def per_request_select(gates: jnp.ndarray, m_r: int, k0: int,
                       *, priors: Optional[jnp.ndarray] = None,
                       corr: float = 1.0) -> jnp.ndarray:
    """Algorithm 3 — per-request greedy selection, vectorized over requests.

    gates: (b, t, E) where t = 1 + L_s tokens of each request.
    Returns per-request masks S_r, shape (b, E).

    Requests whose gate rows are entirely zero (inactive continuous-
    batching slots, compute-masked out of routing) select nothing: the
    greedy pool would otherwise rank an all-zero score vector and emit
    the first m_r expert indices.

    priors: optional (b, E) per-request gate histograms from *earlier*
    decode rounds of the same requests (Assumption 4.1's intra-request
    correlation, carried across draft/verify passes by the scheduler).
    Each request's greedy score becomes agg + corr * |agg|_1 * prior_hat,
    i.e. the prior redistributes up to a `corr` fraction of the request's
    current gate mass toward its historically preferred experts — scale-
    matched so the blend is invariant to the number of live tokens.
    """
    s0 = warmup_union(gates, k0)              # (b, E)
    agg = gates.sum(axis=-2)                  # (b, E)
    live = agg.sum(-1, keepdims=True) > 0     # (b, 1)
    if priors is not None and corr > 0.0:
        pnorm = priors / jnp.maximum(priors.sum(-1, keepdims=True), 1e-30)
        agg = agg + corr * agg.sum(-1, keepdims=True) * pnorm
    if m_r <= 0:
        return s0
    pool = jnp.where(s0, -jnp.inf, agg)
    picked = topk_mask(pool, min(m_r, gates.shape[-1]))
    return s0 | (picked & live)


def spec_select(gates: jnp.ndarray, m: int, m_r: int, k0: int,
                *, priors: Optional[jnp.ndarray] = None,
                corr: float = 1.0) -> jnp.ndarray:
    """Algorithm 4 — speculative-decoding-aware hierarchical selection.

    Exploits intra-request expert-preference correlation (Assumption 4.1):
    each request first gets its own small budget m_r (warm-up k0 inside),
    the per-request sets are unioned, and batch-level greedy tops up to
    the batch budget m.

    With `priors` (per-request gate histograms collected by the scheduler
    across earlier rounds) the selection becomes correlation-aware at
    both levels: per-request scores blend each request's own history
    (see per_request_select) and the batch-level top-up blends the
    mass-weighted mixture of all live requests' histories, so experts
    that several co-batched requests have favored before win ties over
    one-off spikes in the current draft window.

    gates: (b, 1+L_s, E). Returns S_batch, shape (E,).
    """
    s_r = per_request_select(gates, m_r, k0, priors=priors, corr=corr)
    s_batch = s_r.any(axis=0)                 # union across requests
    flat = gates.reshape(-1, gates.shape[-1])
    if priors is not None and corr > 0.0:
        pnorm = priors / jnp.maximum(priors.sum(-1, keepdims=True), 1e-30)
        req_mass = gates.sum(axis=(-2, -1), keepdims=False)      # (b,)
        blended = flat.sum(0) + corr * (pnorm * req_mass[:, None]).sum(0)
        if m <= 0:
            return s_batch
        pool = jnp.where(s_batch, -jnp.inf, blended)
        return s_batch | topk_mask(pool, min(m, gates.shape[-1]))
    return greedy_select(flat, m, s_batch)


def ep_select(gates: jnp.ndarray, m_g: int, num_groups: int, k0: int,
              *, strict_cap: bool = True) -> jnp.ndarray:
    """Algorithms 5+6 — expert-parallelism-aware selection.

    Experts are partitioned contiguously into `num_groups` device groups
    (group g owns experts [g*E/G, (g+1)*E/G) — exactly how the expert
    axis shards over the mesh "model" axis). Round-robin greedy over
    groups with independent per-group budgets is equivalent to taking
    the top-m_g experts *within each group* by aggregated score, which
    enforces MaxLoad(S) <= m_g by construction.

    strict_cap=True (default) counts warm-up members against the group
    budget (warm-up experts get +BIG priority so they are kept first),
    guaranteeing the paper's MaxLoad bound. strict_cap=False unions the
    warm-up set on top (load may exceed m_g where warm-up is dense).

    gates: (T, E). Returns mask (E,).

    Non-divisible E: groups are ceil(E/G) wide, the last group(s)
    smaller — the padding slots carry -inf priority and are sliced off,
    so they can never absorb a group's budget pick that a real expert
    wanted (they only get picked when the group has fewer than m_g real
    experts, in which case the slice discards them).
    """
    T, E = gates.shape
    per = -(-E // num_groups)
    s0 = warmup_union(gates, k0)              # (E,)
    agg = gates.sum(axis=0)                   # (E,)
    if m_g <= 0:
        return s0 if not strict_cap else jnp.zeros((E,), bool)
    prio = agg + _BIG * s0.astype(agg.dtype)
    prio = jnp.pad(prio, (0, num_groups * per - E),
                   constant_values=-jnp.inf)
    grouped = prio.reshape(num_groups, per)
    picked = topk_mask(grouped, min(m_g, per)).reshape(-1)[:E]
    if strict_cap:
        return picked
    return picked | s0


def restricted_topk(gates: jnp.ndarray, mask: jnp.ndarray, k: int,
                    *, logits: Optional[jnp.ndarray] = None,
                    normalize: bool = True
                    ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Refinement step — per-token top-k routing *within* the selected set.

    gates: (T, E) full probabilities; mask: (E,) the XShare set S.
    Returns (indices (T, k), weights (T, k)). Weights renormalize the
    selected logits (softmax over the chosen k), matching Sec 2.2's
    gating; entries whose expert fell outside S (possible when |S| < k)
    get zero weight.

    If `logits` (pre-softmax router outputs) is given, the renormalized
    weights use them directly — numerically identical to softmax over
    probabilities up to the shared normalizer.
    """
    T, E = gates.shape
    k = min(k, E)
    masked = jnp.where(mask[None, :], gates, -jnp.inf)
    top_g, idx = jax.lax.top_k(masked, k)     # (T, k)
    valid = jnp.isfinite(top_g)
    if normalize:
        src = logits if logits is not None else jnp.log(
            jnp.clip(gates, 1e-30, None))
        sel_logits = jnp.take_along_axis(src, idx, axis=-1)
        sel_logits = jnp.where(valid, sel_logits, -jnp.inf)
        w = jax.nn.softmax(sel_logits, axis=-1)
        w = jnp.where(valid, w, 0.0)
        # all-invalid row (|S| == 0): zero weights, not NaN
        w = jnp.where(valid.any(axis=-1, keepdims=True), w, 0.0)
    else:
        w = jnp.where(valid, top_g, 0.0)
    return idx, w


# ------------------------------------------------- scheduling affinity ----
#
# The paper's correlation-aware selection lifted one level up, to the
# serving scheduler: instead of (only) shrinking the expert set for a
# batch we are handed, *compose* the batch so its requests already share
# experts. A request is summarized by its gate histogram (mean router
# probability vector over its prompt tokens); the admission policy
# greedily admits the waiting request whose histogram overlaps the
# running batch's aggregated gate mass the most.

def gate_histogram(gates: jnp.ndarray) -> jnp.ndarray:
    """Mean router probability vector over tokens. (..., T, E) -> (..., E).

    The natural request summary under the paper's modular proxy
    objective: the batch-level aggregated utility of expert j is just
    the sum of the member histograms' entries at j.
    """
    return gates.mean(axis=-2)


def affinity_score(cand_hist: jnp.ndarray,
                   batch_mass: jnp.ndarray) -> jnp.ndarray:
    """Histogram-intersection affinity between a candidate request and
    the running batch's aggregated gate mass.

    Both sides are normalized to unit mass, so the score is the shared
    gate probability mass: 1.0 = identical expert usage, 0.0 = fully
    disjoint. cand_hist: (..., E); batch_mass: (E,). Returns (...,).
    Against an empty batch (all-zero mass) every candidate scores 0 —
    ties that callers break FIFO.
    """
    c = cand_hist / jnp.maximum(
        cand_hist.sum(-1, keepdims=True), 1e-30)
    b = batch_mass / jnp.maximum(batch_mass.sum(-1, keepdims=True), 1e-30)
    return jnp.minimum(c, b).sum(-1)


def rank_by_affinity(cand_hists: jnp.ndarray,
                     batch_mass: jnp.ndarray) -> jnp.ndarray:
    """Affinity score per waiting request. (N, E), (E,) -> (N,) scores;
    the greedy admission policy admits argmax (first index on ties, so an
    empty batch degenerates to FIFO)."""
    return affinity_score(cand_hists, batch_mass[None, :])


def apply_policy(gates: jnp.ndarray, policy, *, top_k: int,
                 spec_shape: Optional[Tuple[int, int]] = None,
                 logits: Optional[jnp.ndarray] = None,
                 priors: Optional[jnp.ndarray] = None):
    """Dispatch a full XSharePolicy at one MoE layer.

    gates: (T, E) full router probabilities (T = all tokens this step).
    spec_shape: (num_requests, tokens_per_request) — required for
    mode="spec"; T must equal their product.
    priors: optional (num_requests, E) gate-histogram priors for
    mode="spec" correlation-aware selection (weight `policy.corr`).

    Returns (indices (T, top_k), weights (T, top_k), mask (E,)).
    """
    T, E = gates.shape
    mode = policy.mode
    if mode == "off":
        mask = jnp.ones((E,), dtype=bool)
    elif mode == "batch":
        mask = batch_select(gates, policy.m_l, policy.k0)
    elif mode == "spec":
        if spec_shape is None:
            raise ValueError("mode='spec' needs spec_shape=(b, 1+L_s)")
        b, t = spec_shape
        assert b * t == T, (b, t, T)
        mask = spec_select(gates.reshape(b, t, E), policy.m_l,
                           policy.m_r, policy.k0, priors=priors,
                           corr=policy.corr)
    elif mode == "ep":
        mask = ep_select(gates, policy.m_g, policy.num_groups, policy.k0,
                         strict_cap=policy.strict_cap)
    else:
        raise ValueError(f"unknown XShare mode {mode!r}")
    idx, w = restricted_topk(gates, mask, top_k, logits=logits)
    return idx, w, mask
