"""XShare core — batch-aware expert selection (the paper's contribution)."""
from repro.core.selection import (  # noqa: F401
    topk_mask, warmup_union, greedy_select, batch_select,
    per_request_select, spec_select, ep_select, restricted_topk,
    apply_policy, gate_histogram, affinity_score, rank_by_affinity,
)
from repro.core import routing, metrics  # noqa: F401
from repro.configs.base import XSharePolicy  # noqa: F401
