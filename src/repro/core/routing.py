"""Router / gating primitives shared by the MoE layers and the core algos."""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp


def router_probs(x: jnp.ndarray, w_g: jnp.ndarray,
                 bias: Optional[jnp.ndarray] = None
                 ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Full gating distribution g(x) = softmax(W_g x) over all experts.

    x: (T, d), w_g: (d, E). Returns (logits (T, E), probs (T, E)) in f32 —
    routing decisions are always taken in full precision.
    """
    logits = jnp.asarray(x, jnp.float32) @ jnp.asarray(w_g, jnp.float32)
    if bias is not None:
        logits = logits + bias
    return logits, jax.nn.softmax(logits, axis=-1)


def topk_route(logits: jnp.ndarray, k: int, *, normalize: bool = True
               ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Vanilla per-token top-k routing (Sec 2.2): indices + gate weights.

    Weights are softmax over the selected logits when normalize=True
    (Mixtral/DeepSeek convention), else raw softmax probabilities of the
    full distribution at the selected slots.
    """
    top_l, idx = jax.lax.top_k(logits, k)
    if normalize:
        w = jax.nn.softmax(top_l, axis=-1)
    else:
        probs = jax.nn.softmax(logits, axis=-1)
        w = jnp.take_along_axis(probs, idx, axis=-1)
    return idx, w


def dispatch_combine_weights(idx: jnp.ndarray, w: jnp.ndarray,
                             num_experts: int
                             ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """GShard-style dense dispatch/combine tensors from sparse routing.

    idx, w: (T, k). Returns (dispatch (T, E) bool — token goes to expert,
    combine (T, E) — gate weight, zero off the routed slots).
    """
    one_hot = jax.nn.one_hot(idx, num_experts, dtype=w.dtype)  # (T,k,E)
    combine = (one_hot * w[..., None]).sum(axis=-2)            # (T,E)
    dispatch = combine > 0
    return dispatch, combine
