"""Checkpointing: pytree <-> npz with path-keyed entries + JSON metadata.

Host-side (np.asarray gathers); fine for the single-process container and
the structure mirrors what a sharded writer would key on (tree paths).
"""
from __future__ import annotations

import json
import os
from typing import Any, Dict, Optional

import jax
import numpy as np


def _flatten(tree) -> Dict[str, Any]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(
            str(getattr(p, "key", getattr(p, "idx", getattr(p, "name", p))))
            for p in path)
        arr = np.asarray(leaf)
        if arr.dtype.kind == "V" or str(arr.dtype) == "bfloat16":
            # npz has no bfloat16 — store upcast, restore re-casts
            arr = np.asarray(jax.numpy.asarray(leaf, jax.numpy.float32))
        flat[key] = arr
    return flat


def save_checkpoint(path: str, tree, *, step: Optional[int] = None,
                    extra: Optional[Dict] = None) -> None:
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    flat = _flatten(tree)
    np.savez(path if path.endswith(".npz") else path + ".npz", **flat)
    meta = {"step": step, "extra": extra or {},
            "keys": sorted(flat), "dtypes": {k: str(v.dtype)
                                             for k, v in flat.items()}}
    with open((path[:-4] if path.endswith(".npz") else path) + ".json",
              "w") as f:
        json.dump(meta, f, indent=1)


def load_checkpoint(path: str):
    """Load a checkpoint WITHOUT a target tree: returns
    ``(flat, meta)`` where ``flat`` maps tree-path keys ("a/b/0") to
    numpy arrays exactly as stored and ``meta`` is the JSON sidecar
    (step / extra / keys / dtypes). bfloat16 leaves come back as the
    stored float32 upcast — ``meta["dtypes"]`` records what was stored;
    callers that know the original dtype re-cast (restore_checkpoint
    does this via the target tree). The serving snapshot layer
    (serving/journal.py) builds on this."""
    base = path[:-4] if path.endswith(".npz") else path
    npz = np.load(base + ".npz")
    flat = {k: npz[k] for k in npz.files}
    meta: Dict[str, Any] = {}
    if os.path.exists(base + ".json"):
        with open(base + ".json") as f:
            meta = json.load(f)
    return flat, meta


def restore_checkpoint(path: str, target):
    """Restore into the structure of `target` (values replaced)."""
    npz = np.load(path if path.endswith(".npz") else path + ".npz")
    paths, treedef = jax.tree_util.tree_flatten_with_path(target)
    leaves = []
    for path_elems, leaf in paths:
        key = "/".join(
            str(getattr(p, "key", getattr(p, "idx", getattr(p, "name", p))))
            for p in path_elems)
        arr = npz[key]
        assert arr.shape == np.shape(leaf), (key, arr.shape, np.shape(leaf))
        if isinstance(leaf, np.ndarray):
            # host-side numpy targets keep their exact dtype: routing
            # them through jnp silently clamps int64/float64 to 32-bit
            # under the default x64-disabled config (drift that corrupts
            # e.g. serving-snapshot slot tables and step counters)
            leaves.append(arr.astype(leaf.dtype))
        else:
            leaves.append(jax.numpy.asarray(arr).astype(
                jax.numpy.asarray(leaf).dtype))
    return jax.tree_util.tree_unflatten(treedef, leaves)
