"""§Perf hillclimb driver — the three selected (arch x shape) pairs:

  1. qwen3-moe-235b-a22b x decode_32k — most representative of the
     paper's technique (MoE decode under XShare).
  2. musicgen-large x decode_32k — worst roofline fraction (huge MHA
     KV cache dominates the memory term).
  3. zamba2-1.2b x train_4k — most collective-bound.

Each experiment is one hypothesis->change->re-lower->compare cycle;
results append to hillclimb_results.json (EXPERIMENTS.md §Perf reads
them). Run AFTER the main sweep:

    PYTHONPATH=src python -m repro.launch.hillclimb [--exp qwen3|...]
"""
import os

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", ""))

import argparse      # noqa: E402
import json          # noqa: E402

import jax.numpy as jnp  # noqa: E402

from repro.configs.base import XSharePolicy  # noqa: E402
from repro.configs.registry import get_config  # noqa: E402
from repro.configs.shapes import get_shape  # noqa: E402
from repro.launch.dryrun import lower_one  # noqa: E402
from repro.launch.mesh import make_production_mesh  # noqa: E402

OUT = "hillclimb_results.json"


def record(recs, name, rec, hypothesis):
    rec["experiment"] = name
    rec["hypothesis"] = hypothesis
    recs.append(rec)
    print(f"  -> {name}: mem={rec['memory_s']*1e3:.3f}ms "
          f"coll={rec['collective_s']*1e3:.3f}ms "
          f"comp={rec['compute_s']*1e3:.3f}ms dom={rec['dominant']} "
          f"peak={rec['peak_hbm_gb']:.1f}GB", flush=True)


def exp_qwen3(recs, mesh):
    cfg = get_config("qwen3-moe-235b-a22b")
    shape = get_shape("decode_32k")
    print("[qwen3 decode] baseline-off -> paper-faithful -> EP-aware -> "
          "f8 cache", flush=True)
    record(recs, "qwen3/0-vanilla-topk",
           lower_one(cfg, shape, mesh, policy=XSharePolicy(mode="off")),
           "vanilla routing: at B=128 nearly all 128 experts activate; "
           "expert weights dominate the memory term")
    record(recs, "qwen3/1-paper-xshare-batch",
           lower_one(cfg, shape, mesh,
                     policy=XSharePolicy(mode="batch", k0=1, m_l=16)),
           "PAPER-FAITHFUL Alg2 (k0=1,m=16): selected set ~97 of 128 -> "
           "expert-weight traffic drops ~25%")
    record(recs, "qwen3/2-beyond-ep-aware",
           lower_one(cfg, shape, mesh,
                     policy=XSharePolicy(mode="ep", k0=1, m_g=4,
                                         num_groups=16)),
           "BEYOND: Alg6 with per-shard cap m_g=4 (16 shards): the "
           "bottleneck shard loads 4 experts instead of ~8+, halving "
           "the step's critical-path expert traffic")
    record(recs, "qwen3/3-beyond-ep+f8cache",
           lower_one(cfg, shape, mesh,
                     policy=XSharePolicy(mode="ep", k0=1, m_g=4,
                                         num_groups=16),
                     cache_dtype=jnp.float8_e4m3fn),
           "BEYOND: + f8 KV cache halves the 3.2GB/dev cache read "
           "stream")


def exp_musicgen(recs, mesh):
    cfg = get_config("musicgen-large")
    shape = get_shape("decode_32k")
    print("[musicgen decode] baseline -> f8 cache", flush=True)
    record(recs, "musicgen/0-baseline",
           lower_one(cfg, shape, mesh),
           "MHA kv=32 cache (6.5GB/dev) dominates: memory term ~8ms")
    record(recs, "musicgen/1-beyond-f8cache",
           lower_one(cfg, shape, mesh, cache_dtype=jnp.float8_e4m3fn),
           "BEYOND: f8 KV cache halves cache bytes -> memory term ~4ms")


def exp_zamba(recs, mesh):
    cfg = get_config("zamba2-1.2b")
    shape = get_shape("train_4k")
    print("[zamba2 train] baseline -> no-FSDP -> no-seqpar", flush=True)
    record(recs, "zamba2/0-baseline-fsdp",
           lower_one(cfg, shape, mesh),
           "FSDP(data) x TP: per-layer param all-gathers dominate the "
           "collective term for a 1.2B model that would fit replicated")
    record(recs, "zamba2/1-beyond-nofsdp",
           lower_one(cfg, shape, mesh, fsdp=False),
           "BEYOND: drop FSDP for small models (params replicated over "
           "data): forward/backward param all-gathers vanish; grads "
           "still all-reduce")
    record(recs, "zamba2/2-nofsdp-noseqpar",
           lower_one(cfg, shape, mesh, fsdp=False,
                     disable_constraints=("seqpar",)),
           "ablation: also drop sequence parallelism -> fewer "
           "per-layer gathers but 16x larger activation checkpoints "
           "(expect memory regression)")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--exp", default="all",
                    choices=["all", "qwen3", "musicgen", "zamba2"])
    args = ap.parse_args()
    mesh = make_production_mesh()
    recs = []
    if os.path.exists(OUT):
        recs = json.load(open(OUT))
    if args.exp in ("all", "qwen3"):
        exp_qwen3(recs, mesh)
    if args.exp in ("all", "musicgen"):
        exp_musicgen(recs, mesh)
    if args.exp in ("all", "zamba2"):
        exp_zamba(recs, mesh)
    json.dump(recs, open(OUT, "w"), indent=1)
    print("wrote", OUT, flush=True)


if __name__ == "__main__":
    main()
