"""Production mesh construction.

A FUNCTION, not a module constant: importing this module never touches
jax device state. The dry-run entry point sets
XLA_FLAGS=--xla_force_host_platform_device_count=512 before any jax
import; smoke tests and benchmarks see the real (1-device) platform.

Target hardware (TPU v5e pod): 16x16 = 256 chips per pod; multi-pod is
2 pods = 512 chips with the "pod" axis crossing DCI.
"""
from __future__ import annotations

import jax


def make_mesh_compat(shape, axes):
    """jax.make_mesh across jax versions: AxisType (and the axis_types
    kwarg) only exist in newer releases; older ones default to Auto
    semantics anyway."""
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is None:
        return jax.make_mesh(shape, axes)
    return jax.make_mesh(shape, axes,
                         axis_types=(axis_type.Auto,) * len(axes))


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh_compat(shape, axes)


def make_host_mesh(model: int = 1):
    """Small mesh over however many devices exist (tests/examples)."""
    n = len(jax.devices())
    data = n // model
    return make_mesh_compat((data, model), ("data", "model"))
