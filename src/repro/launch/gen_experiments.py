"""Assemble EXPERIMENTS.md from dryrun_results.json,
hillclimb_results.json, and benchmarks/results.json.

    PYTHONPATH=src python -m repro.launch.gen_experiments
"""
from __future__ import annotations

import json
import os

HEADER = """# EXPERIMENTS — XShare reproduction on the TPU v5e production mesh

All artifacts regenerate with:

```bash
PYTHONPATH=src python -m repro.launch.dryrun --all        # §Dry-run/§Roofline data
PYTHONPATH=src python -m repro.launch.hillclimb           # §Perf data
PYTHONPATH=src python -m benchmarks.run                   # §Paper-claims data
PYTHONPATH=src python -m repro.launch.gen_experiments     # this file
```

Hardware model: TPU v5e — 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link
ICI. Meshes: single-pod 16x16 ("data","model"), multi-pod 2x16x16
("pod","data","model"). Params/caches bf16, optimizer f32.

### Methodology notes (read first)

* **Lower+compile**: every (architecture x shape x mesh) combination
  lowers and compiles with ShapeDtypeStruct inputs on 512 forced host
  devices — 80/80 pass (this is the multi-pod dry-run deliverable).
* **XLA-CPU measurement caveats** (the runtime here is CPU; TPU is the
  *target*):
  1. `cost_analysis()` counts while-loop bodies ONCE (verified with a
     scan microbenchmark), so compute/memory roofline terms are
     **analytic closed forms** over the exact program structure we
     compiled (layer/chunk/microbatch trip counts are ours by
     construction); raw HLO numbers are kept in the records.
  2. Collective bytes are parsed from the compiled HLO per op, split
     into inside-loop-body vs outside, and the inside share is scaled
     by the layer-scan trip count.
  3. `memory_analysis()` is inflated for bf16 models because XLA-CPU
     float-normalization materializes f32 copies of bf16 loop-carried
     state (caches, checkpoint stacks) — native-bf16 TPUs don't do
     this. Records therefore carry `analytic` per-device params / opt /
     cache footprints computed exactly from the sharding specs; the
     five combos whose CPU peak exceeds 16 GB all have analytic state
     far under it (e.g. musicgen decode: 23.1 GB CPU peak vs
     0.02 params + 6.5 cache analytic).
* Decode shapes lower `serve_step` (ONE token against the cache);
  long_500k runs natively on SSM/hybrid, with native SWA on h2o-danube,
  and as the documented sliding-window variant (window 4096) on the
  full-attention archs — no architecture skips any shape.
* MoE decode shapes compile with the PAPER-FAITHFUL XShare policy
  (Alg 2, k0=1, m_l=16) — the technique is a first-class routing mode,
  not a bolt-on.
"""


def fmt(x, p=3):
    return f"{x:.{p}f}"


def dryrun_section(records) -> str:
    out = ["## §Dry-run — 10 architectures x 4 shapes x 2 meshes\n",
           "80/80 combinations lower + compile. Per-device figures from "
           "`memory_analysis()` / `cost_analysis()` (raw, see caveats) "
           "plus exact analytic state footprints.\n"]
    out.append("| arch | shape | mesh | policy | CPU peak GB | analytic "
               "state GB | coll bytes/dev (in-loop + outside) | "
               "compile s |")
    out.append("|---|---|---|---|---|---|---|---|")
    for r in sorted(records, key=lambda r: (r["arch"], r["shape"],
                                            r["mesh"])):
        an = r.get("analytic", {})
        an_s = " + ".join(f"{k[:-3]} {v}" for k, v in an.items()) or "-"
        coll = (f"{r.get('collective_bytes_inside_loop', 0)/1e6:.1f}M x"
                f"{r.get('collective_trip_correction', 1)} + "
                f"{r.get('collective_bytes_outside_loop', 0)/1e6:.1f}M")
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | {r['policy']} "
            f"| {r['peak_hbm_gb']:.2f} | {an_s} | {coll} "
            f"| {r['compile_s']} |")
    return "\n".join(out)


def roofline_section(records) -> str:
    out = ["\n## §Roofline — single-pod (16x16), per-device step terms\n",
           "compute = analytic FLOPs/dev / 197e12; memory = analytic "
           "HBM bytes/dev / 819e9 (decode uses bottleneck-shard expert "
           "accounting); collective = HLO-parsed bytes (in-loop x "
           "layer-trips + outside) / 50e9. useful = MODEL_FLOPS "
           "(6ND-convention) / analytic FLOPs.\n"]
    out.append("| arch | shape | compute ms | memory ms | collective ms "
               "| dominant | MODEL_FLOPS | useful | one-line fix |")
    out.append("|---|---|---|---|---|---|---|---|---|")
    fixes = {
        "memory": "shrink resident stream: fewer activated experts "
                  "(XShare), f8 cache, window",
        "collective": "cut per-layer gathers: no-FSDP for small "
                      "models, head-local caches, overlap",
        "compute": "raise MFU: larger per-device batch, fused kernels, "
                   "less remat",
    }
    for r in sorted(records, key=lambda r: (r["shape"], r["arch"])):
        if r["mesh"] != "16x16":
            continue
        out.append(
            f"| {r['arch']} | {r['shape']} "
            f"| {fmt(r['compute_s']*1e3)} | {fmt(r['memory_s']*1e3)} "
            f"| {fmt(r['collective_s']*1e3)} | **{r['dominant']}** "
            f"| {r.get('model_flops', 0):.2e} "
            f"| {fmt(r.get('useful_ratio', 0))} "
            f"| {fixes[r['dominant']]} |")
    out.append("""
Reading the table:
* **decode_32k is memory/collective-bound everywhere** — the paper's
  regime. For the MoE archs the memory term is expert-weight streaming
  (bottleneck shard), which is exactly what XShare shrinks.
* **prefill/train are compute-bound** for the dense archs with useful
  ratios 0.6-0.75 (the gap is attention quadratic work + heads/router/
  vocab overheads over the 6ND convention; >1 for zamba2/mamba2 means
  weight sharing / scan recompute make HLO work smaller than 6ND).
* **zamba2 is collective-bound** in train/prefill: a 1.2B-param model
  paying per-layer FSDP gathers + 7 shared-attention seq-par gathers —
  see §Perf iteration 3 for the fix.
* long_500k steps are sub-millisecond: state-space / windowed caches
  make 500k-token contexts decode-cheap by construction.""")
    return "\n".join(out)


def perf_section(recs) -> str:
    out = ["\n## §Perf — hillclimb on the three selected pairs\n",
           "Pairs: qwen3-moe x decode_32k (paper-representative), "
           "musicgen x decode_32k (worst roofline fraction), zamba2 x "
           "train_4k (most collective-bound). Each row is one "
           "hypothesis -> change -> re-lower -> measure cycle.\n"]
    out.append("| experiment | hypothesis | compute ms | memory ms | "
               "collective ms | dominant | CPU peak GB |")
    out.append("|---|---|---|---|---|---|---|")
    for r in recs:
        out.append(
            f"| {r['experiment']} | {r['hypothesis']} "
            f"| {fmt(r['compute_s']*1e3)} | {fmt(r['memory_s']*1e3)} "
            f"| {fmt(r['collective_s']*1e3)} | {r['dominant']} "
            f"| {r['peak_hbm_gb']:.1f} |")
    return "\n".join(out)


def bench_section(bench) -> str:
    out = ["\n## §Paper-claims — benchmark outputs vs the paper\n"]
    rows = {
        "fig1_activation":
            "Fig 1 / E[N_a] formula: empirical activation within "
            "{derived:.1%} of N(1-(1-k/N)^B) across both router "
            "geometries; DSR1 B=8 -> {dsr1_b8:.0f} (paper ~57), "
            "B=32 -> {dsr1_b32:.0f} (paper ~163).",
        "fig3_overlap":
            "Fig 3: consecutive-token top-5 expert overlap is "
            "{derived:.1f}x the cross-dataset overlap (paper: 2-3x); "
            "ordering consecutive >= same-dataset >= cross reproduced.",
        "fig4_table3_tradeoff":
            "Fig 4/Table 3 (Alg 2, BS=16): activated experts cut "
            "{derived:.0%} at the (m=16,k0=1)-equivalent config "
            "(paper: up to 30%), CE delta {ce:.3f} nats; the "
            "warm-up-only (0,1) config is fastest but degrades most — "
            "same Pareto structure as the paper.",
        "fig5_table4_spec":
            "Fig 5/Table 4 (Alg 4, BS=4, L_s=3): hierarchical "
            "selection gains {derived:.0%} modeled OTPS at CE delta "
            "{ce:.3f}; configs without warm-up degrade hardest "
            "(paper's (0,16,4) observation).",
        "table1_mixed":
            "Table 1 (mixed 4-dataset batch): Alg 4 keeps its gains "
            "({derived:.0%} modeled OTPS) under heterogeneous "
            "requests.",
        "table2_ep":
            "Table 2 (EP, DSR1 geometry 256e/8k): Alg 6 (k0=1,m_g=5) "
            "cuts activated experts {drop:.0%} (paper 73%) and peak "
            "per-group load {ratio:.1f}x (paper 3.0x) at CE delta "
            "{ce:.3f}; MaxLoad<=m_g bound holds.",
        "bs_ablation":
            "Appendix-B batch ablation: at fixed relative budget the "
            "activated-expert reduction is {derived:.0%} at BS=4, "
            "peaks near BS=16, and the CE penalty shrinks with batch "
            "(more tokens vote for the shared set).",
        "kernels_bench":
            "Kernel byte model: at 25% expert activation the masked "
            "Pallas FFN moves {derived:.0%} of the dense HBM bytes "
            "(kernel==oracle to 1e-4).",
    }
    for name, tpl in rows.items():
        b = bench.get(name)
        if not b:
            continue
        kw = dict(derived=b.get("derived"))
        if name == "fig1_activation":
            kw.update(dsr1_b8=b["dsr1_b8"], dsr1_b32=b["dsr1_b32"])
        if name == "fig4_table3_tradeoff":
            kw.update(ce=b.get("ce_delta_at_(4,1)", float("nan")))
        if name == "fig5_table4_spec":
            kw.update(ce=b.get("spec_ce_delta_best", float("nan")))
        if name == "table2_ep":
            d = b["derived"]
            kw = dict(drop=d["experts_drop"],
                      ratio=d["peak_load_ratio"], ce=d["ce_delta"])
        try:
            out.append("* " + tpl.format(**kw))
        except Exception:  # noqa: BLE001
            out.append(f"* {name}: {b.get('derived')}")
    out.append(
        "\nContext: paper OTPS gains (7-14%) are measured wall-clock on "
        "H100s where expert loads partially overlap compute; our "
        "modeled OTPS is the memory-bound byte-ratio upper bound, so "
        "it is systematically larger. The *accuracy-vs-budget* "
        "structure, activation-reduction magnitudes, overlap ratios, "
        "and EP load bounds are the reproduced quantities. Full row "
        "data: benchmarks/results.json.")
    return "\n".join(out)


def main() -> None:
    records = json.load(open("dryrun_results.json"))
    if os.path.exists("dryrun_paper_models.json"):
        extras = json.load(open("dryrun_paper_models.json"))
        for e in extras:
            e["shape"] = e["shape"] + " (extra)"
        records = records + extras
    parts = [HEADER, dryrun_section(records), roofline_section(records)]
    if os.path.exists("hillclimb_results.json"):
        parts.append(perf_section(json.load(
            open("hillclimb_results.json"))))
        parts.append(PERF_NARRATIVE)
    bpath = os.path.join("benchmarks", "results.json")
    if os.path.exists(bpath):
        parts.append(bench_section(json.load(open(bpath))))
    with open("EXPERIMENTS.md", "w") as f:
        f.write("\n".join(parts) + "\n")
    print("wrote EXPERIMENTS.md")


PERF_NARRATIVE = """
### §Perf narrative (hypothesis log, real numbers from the table above)

**1. qwen3-moe-235b x decode_32k — the paper's setting.**
Napkin math: B=128 decode tokens, E=128, k=8 -> vanilla activation
E[N_a] ~ 127.97/128: every expert streams from HBM every step; expert
weights dominate the 7.90 ms memory term.
*It. 1 — PAPER-FAITHFUL (Alg 2, k0=1, m=16):* expected selected set
~97/128 -> memory 7.90 -> 7.18 ms (-9%). CONFIRMED but small: at
B=128 the warm-up union alone covers ~81 experts — the paper's own
BS=16 sweet spot (benchmarks, 30-47% cuts) shrinks at production batch
sizes. This is the reproduction baseline, recorded separately.
*It. 2 — BEYOND (Alg 6 as the default TPU decode policy, m_g=4 x 16
shards):* the step waits on the hottest expert shard; capping it at 4
experts (vs ~8.6 expected under Alg 2) cuts the bottleneck stream:
7.18 -> 5.02 ms (-30%). CONFIRMED. The paper uses Alg 6 only for the
DSR1/GPU case; making it the default on the expert-parallel mesh axis
is the beyond-paper change.
*It. 3 — BEYOND (f8 KV cache):* halves the 3.2 GB/dev cache stream:
5.02 -> 3.09 ms; the step is now COLLECTIVE-bound (3.5 ms all-to-all)
— total memory-term reduction 2.6x over vanilla, 2.3x over the
paper-faithful configuration. Next lever would be all-to-all overlap.

**2. musicgen-large x decode_32k — worst roofline fraction (0.28).**
MHA (kv=32) cache = 6.5 GB/dev -> memory term 7.89 ms vs 0.045 ms
compute. (Head-sharded cache layout, kv=32 | model axis, already
removed the distributed-softmax collectives during bring-up: coll term
0.25 ms.) *Iteration — BEYOND (f8 cache):* 7.89 -> 3.96 ms memory
(-50%, exactly the byte ratio; CONFIRMED), CPU peak 23.1 -> 11.7 GB.
Remaining step time is pure cache bandwidth — the architecture-level
fix (GQA) is out of scope for a serving framework.

**3. zamba2-1.2b x train_4k — most collective-bound (1.13 s).**
*It. 1 — hypothesis: per-layer FSDP param gathers dominate (1.2B params
buy only ~0.06 GB/dev when sharded).* Disabling FSDP: 1134 -> 1095 ms
(-3.5%). REFUTED — the collective term is NOT param gathers but
activation resharding: seq-parallel gathers around 38 SSM layers + 7
shared-attn blocks, and the xh head-shard constraint forcing a
(gather, re-scatter) pair per layer.
*It. 2 — ablation: drop sequence parallelism too:* 1095 -> 848 ms
(-22%) but checkpoint stacks grow 16x (CPU peak 18 -> 45 GB) — a real
memory-for-collectives trade that does NOT fit v5e; rejected, seq-par
kept. The refutation is the finding: for SSM-heavy hybrids the right
fix is a sequence-parallel SSD with halo-exchange conv (K-1=3 elements
via collective-permute) and cross-shard chunk-state passing, so x never
re-gathers — designed (kernels/ssd_scan.py's chunk states are already
the objects a collective-permute chain would carry) but not landed
here; estimated to remove most of the remaining ~0.85 s.

Stopping: per pair, three remaining candidates each projected <5% on
the dominant term (overlap scheduling is a compiler/latency-hiding
change, not visible in these static terms) — stopped per protocol.
"""


if __name__ == "__main__":
    main()
