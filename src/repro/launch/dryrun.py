"""Multi-pod dry-run: lower + compile every (arch x shape x mesh)
combination on the production mesh with ShapeDtypeStruct inputs (no
allocation), and record memory/cost/collective analyses for §Roofline.

    PYTHONPATH=src python -m repro.launch.dryrun --arch llama3-8b \
        --shape decode_32k [--multi-pod] [--out results.json]
    PYTHONPATH=src python -m repro.launch.dryrun --all

The XLA_FLAGS line below MUST run before any other import that touches
jax: jax locks the device count on first backend init.
"""
import os

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", ""))

import argparse        # noqa: E402
import json            # noqa: E402
import time            # noqa: E402
import traceback       # noqa: E402
from typing import Dict, Optional  # noqa: E402

import jax             # noqa: E402
import jax.numpy as jnp  # noqa: E402

from repro.configs.base import ArchConfig, ShapeConfig  # noqa: E402
from repro.configs.registry import assigned_names, get_config  # noqa: E402
from repro.configs.shapes import SHAPES, get_shape  # noqa: E402
from repro.launch import partition as PT  # noqa: E402
from repro.launch import roofline as RL  # noqa: E402
from repro.launch.inputs import (  # noqa: E402
    decode_cache_len, force_window_for, input_specs, policy_for)
from repro.launch.mesh import make_production_mesh  # noqa: E402
from repro.launch.serve import make_prefill, make_serve_step  # noqa: E402
from repro.launch.train import make_train_step  # noqa: E402
from repro.models import init_params  # noqa: E402
from repro.optim.adamw import AdamWState  # noqa: E402
from repro.sharding import mesh_context  # noqa: E402

PARAM_DTYPE = jnp.bfloat16


def _params_sds(cfg: ArchConfig):
    return jax.eval_shape(
        lambda: init_params(cfg, jax.random.PRNGKey(0), PARAM_DTYPE))


def _opt_sds(params_sds):
    return AdamWState(
        step=jax.ShapeDtypeStruct((), jnp.int32),
        mu=jax.tree_util.tree_map(
            lambda s: jax.ShapeDtypeStruct(s.shape, jnp.float32),
            params_sds),
        nu=jax.tree_util.tree_map(
            lambda s: jax.ShapeDtypeStruct(s.shape, jnp.float32),
            params_sds))


def per_device_gb(sds_tree, spec_tree, mesh) -> float:
    """Exact per-device bytes of a sharded pytree (from its specs)."""
    total = 0.0
    flat_s, _ = jax.tree_util.tree_flatten(sds_tree)
    flat_p = jax.tree_util.tree_flatten(
        spec_tree, is_leaf=lambda x: isinstance(
            x, jax.sharding.PartitionSpec))[0]
    for sds, spec in zip(flat_s, flat_p):
        n = 1
        for d in sds.shape:
            n *= d
        shard = 1
        for entry in spec:
            if entry is None:
                continue
            axes = entry if isinstance(entry, tuple) else (entry,)
            for a in axes:
                shard *= mesh.shape[a]
        total += n * sds.dtype.itemsize / shard
    return total / 1e9


def lower_one(cfg: ArchConfig, shape: ShapeConfig, mesh, *,
              policy=None, capacity_factor: float = 1.25,
              fsdp: bool = True, cache_dtype=None,
              disable_constraints=(),
              extra_tags: Optional[Dict] = None) -> Dict:
    """Lower + compile one combination; return the §Dry-run record."""
    n_dev = mesh.devices.size
    policy = policy if policy is not None else policy_for(cfg, shape)
    fw = force_window_for(cfg, shape) if shape.kind != "train" else None
    accum = 8 if (shape.kind == "train"
                  and RL.param_counts(cfg)["total"] > 50e9) else 1
    ba = PT.batch_axes(mesh, shape.global_batch)
    pspecs = PT.param_specs(cfg, mesh, _params_sds(cfg), fsdp=fsdp)
    ins = input_specs(cfg, shape, PARAM_DTYPE,
                      cache_dtype=cache_dtype)
    t0 = time.perf_counter()

    with mesh_context(mesh, ba, disable=disable_constraints):
        if shape.kind == "train":
            # >50B-param models microbatch 8x to fit activations in HBM
            fn = make_train_step(cfg, policy=policy, remat=True,
                                 capacity_factor=capacity_factor,
                                 accum_steps=accum)
            ospecs = PT.opt_specs(pspecs)
            tspec = PT.token_spec(cfg, mesh, shape.global_batch)
            in_shardings = [pspecs, ospecs, tspec]
            args = [_params_sds(cfg), _opt_sds(_params_sds(cfg)),
                    ins["tokens"]]
            if "prefix_embeds" in ins:
                in_shardings.append(
                    PT.prefix_spec(cfg, mesh, shape.global_batch))
                args.append(ins["prefix_embeds"])
            jitted = jax.jit(
                fn,
                in_shardings=tuple(PT.named(mesh, s) for s in in_shardings),
                out_shardings=(PT.named(mesh, pspecs),
                               PT.named(mesh, ospecs), None),
                donate_argnums=(0, 1))
        elif shape.kind == "prefill":
            fn = make_prefill(cfg, cache_len=shape.seq_len + 512,
                              force_window=fw,
                              capacity_factor=capacity_factor)
            cspecs = PT.cache_specs(cfg, mesh, shape.global_batch)
            tspec = PT.token_spec(cfg, mesh, shape.global_batch)
            in_shardings = [pspecs, tspec]
            args = [_params_sds(cfg), ins["tokens"]]
            if "prefix_embeds" in ins:
                in_shardings.append(
                    PT.prefix_spec(cfg, mesh, shape.global_batch))
                args.append(ins["prefix_embeds"])
            lspec = PT.logits_spec(cfg, mesh, shape.global_batch,
                                   with_seq=False)
            jitted = jax.jit(
                fn,
                in_shardings=tuple(PT.named(mesh, s) for s in in_shardings),
                out_shardings=(PT.named(mesh, lspec),
                               PT.named(mesh, cspecs), None))
        else:  # decode
            fn = make_serve_step(cfg, policy=policy, force_window=fw,
                                 capacity_factor=capacity_factor)
            cspecs = PT.cache_specs(cfg, mesh, shape.global_batch)
            tspec = PT.token_spec(cfg, mesh, shape.global_batch)
            lspec = PT.logits_spec(cfg, mesh, shape.global_batch,
                                   with_seq=True)
            jitted = jax.jit(
                fn,
                in_shardings=(PT.named(mesh, pspecs),
                              PT.named(mesh, tspec),
                              PT.named(mesh, cspecs)),
                out_shardings=(PT.named(mesh, lspec),
                               PT.named(mesh, cspecs), None),
                donate_argnums=(2,))
            args = [_params_sds(cfg), ins["tokens"], ins["cache"]]

        lowered = jitted.lower(*args)
        compiled = lowered.compile()

    ca = compiled.cost_analysis() or {}
    if isinstance(ca, (list, tuple)):   # older jax: one dict per program
        ca = ca[0] if ca else {}
    ma = compiled.memory_analysis()
    hlo_text = compiled.as_text()
    coll = RL.collective_bytes(hlo_text)
    coll_split = RL.collective_bytes_split(hlo_text)
    # exact per-device state footprints from the sharding specs — the
    # TPU-native numbers (XLA-CPU float-normalization duplicates bf16
    # loop-carried state in f32, inflating peak_hbm_gb; see
    # EXPERIMENTS.md §Dry-run notes)
    analytic = {"params_gb": per_device_gb(_params_sds(cfg), pspecs, mesh)}
    if shape.kind == "train":
        analytic["opt_gb"] = 2.0 * per_device_gb(
            jax.tree_util.tree_map(
                lambda s: jax.ShapeDtypeStruct(s.shape, jnp.float32),
                _params_sds(cfg)), pspecs, mesh)
    if shape.kind == "decode":
        cspecs_flat = PT.cache_specs(cfg, mesh, shape.global_batch)
        analytic["cache_gb"] = per_device_gb(ins["cache"], cspecs_flat,
                                             mesh)
    rec = {
        "arch": cfg.name,
        "shape": shape.name,
        "mesh": "x".join(str(mesh.shape[a]) for a in mesh.axis_names),
        "devices": int(n_dev),
        "policy": policy.mode,
        "flops_per_device": float(ca.get("flops", 0.0)),
        "bytes_per_device": float(ca.get("bytes accessed", 0.0)),
        "collective_bytes_per_device": float(sum(coll.values())),
        "collective_bytes_inside_loop": int(coll_split["inside"]),
        "collective_bytes_outside_loop": int(coll_split["outside"]),
        "collectives": {k: int(v) for k, v in coll.items() if v},
        "argument_bytes_per_device": int(ma.argument_size_in_bytes),
        "output_bytes_per_device": int(ma.output_size_in_bytes),
        "temp_bytes_per_device": int(ma.temp_size_in_bytes),
        "alias_bytes_per_device": int(ma.alias_size_in_bytes),
        "peak_hbm_gb": (ma.argument_size_in_bytes
                        + ma.output_size_in_bytes
                        + ma.temp_size_in_bytes
                        - ma.alias_size_in_bytes) / 1e9,
        "compile_s": round(time.perf_counter() - t0, 1),
        "analytic": {k: round(v, 3) for k, v in analytic.items()},
    }
    cbe = 1 if (cache_dtype is not None
                and jnp.dtype(cache_dtype).itemsize == 1) else 2
    rec.update(RL.step_terms(rec, n_dev, cfg, shape, window=fw,
                             accum=accum, policy=policy,
                             cache_bytes_per_el=cbe))
    if extra_tags:
        rec.update(extra_tags)
    return rec


def run(arch: str, shape_name: str, multi_pod: bool, out: Optional[str],
        capacity_factor: float = 1.25) -> Dict:
    cfg = get_config(arch)
    shape = get_shape(shape_name)
    mesh = make_production_mesh(multi_pod=multi_pod)
    rec = lower_one(cfg, shape, mesh, capacity_factor=capacity_factor)
    line = (f"{rec['arch']:22s} {rec['shape']:12s} mesh={rec['mesh']:8s} "
            f"peak={rec['peak_hbm_gb']:.2f}GB "
            f"flops/dev={rec['flops_per_device']:.3e} "
            f"coll/dev={rec['collective_bytes_per_device']:.3e} "
            f"dom={rec['dominant']}")
    print(line, flush=True)
    if out:
        existing = []
        if os.path.exists(out):
            existing = json.load(open(out))
        existing = [r for r in existing
                    if not (r["arch"] == rec["arch"]
                            and r["shape"] == rec["shape"]
                            and r["mesh"] == rec["mesh"])]
        existing.append(rec)
        json.dump(existing, open(out, "w"), indent=1)
    return rec


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="dryrun_results.json")
    args = ap.parse_args(argv)

    if args.all:
        fails = []
        for arch in assigned_names():
            for shape in SHAPES:
                for mp in (False, True):
                    try:
                        run(arch, shape, mp, args.out)
                    except Exception as e:  # noqa: BLE001
                        fails.append((arch, shape, mp, repr(e)))
                        print(f"FAIL {arch} {shape} multi={mp}: {e}",
                              flush=True)
                        traceback.print_exc()
        print(f"\n{len(fails)} failures")
        raise SystemExit(1 if fails else 0)
    run(args.arch, args.shape, args.multi_pod, args.out)


if __name__ == "__main__":
    main()
