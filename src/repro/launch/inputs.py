"""ShapeDtypeStruct input stand-ins per (arch x shape) — shardable,
weak-type-correct, no device allocation — plus the per-combination
decisions (forced sliding window for long-context dense decode, XShare
policy defaults for MoE archs).
"""
from __future__ import annotations

from typing import Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, ShapeConfig, XSharePolicy
from repro.models import init_cache

CACHE_MARGIN = 512      # decode-cache slack: spec verify room + shard-
                        # divisibility alignment (512 | every mesh extent)
LONG_CTX_WINDOW = 4096  # forced sliding window for full-attention archs
                        # at long_500k (DESIGN.md §5)


def force_window_for(cfg: ArchConfig, shape: ShapeConfig) -> Optional[int]:
    """long_500k on a full-attention arch => explicit windowed variant.
    (h2o-danube already has a native 4096 window; ssm/hybrid run native.)"""
    if shape.name != "long_500k" or not cfg.has_attention:
        return None
    if cfg.family == "hybrid":
        return None                   # few shared-attn caches: keep full
    if cfg.attn.sliding_window:
        return None                   # native SWA
    return LONG_CTX_WINDOW


def policy_for(cfg: ArchConfig, shape: ShapeConfig) -> XSharePolicy:
    """Paper-faithful default: XShare batch-aware selection on MoE decode
    (Alg 2, the (m_l=16, k0=1) configuration of Table 3)."""
    if cfg.has_moe and shape.kind == "decode":
        return XSharePolicy(mode="batch", k0=1, m_l=16)
    return XSharePolicy(mode="off")


def decode_cache_len(cfg: ArchConfig, shape: ShapeConfig) -> int:
    return shape.cache_len + CACHE_MARGIN


def input_specs(cfg: ArchConfig, shape: ShapeConfig,
                dtype=jnp.bfloat16, cache_dtype=None) -> Dict:
    """Returns {tokens, prefix_embeds?, cache?} of ShapeDtypeStructs."""
    B = shape.global_batch
    out: Dict = {}
    tok = jnp.int32
    if shape.kind in ("train", "prefill"):
        S = shape.seq_len - cfg.prefix_len
        if cfg.family == "audio":
            out["tokens"] = jax.ShapeDtypeStruct((B, S, cfg.num_codebooks),
                                                 tok)
        else:
            out["tokens"] = jax.ShapeDtypeStruct((B, S), tok)
        if cfg.prefix_len:
            out["prefix_embeds"] = jax.ShapeDtypeStruct(
                (B, cfg.prefix_len, cfg.d_model), dtype)
    else:  # decode
        if cfg.family == "audio":
            out["tokens"] = jax.ShapeDtypeStruct((B, 1, cfg.num_codebooks),
                                                 tok)
        else:
            out["tokens"] = jax.ShapeDtypeStruct((B, 1), tok)
        fw = force_window_for(cfg, shape)
        C = decode_cache_len(cfg, shape)
        cdt = cache_dtype or dtype
        out["cache"] = jax.eval_shape(
            lambda: init_cache(cfg, B, C, cdt, force_window=fw))
    return out
