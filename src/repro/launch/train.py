"""Training step + launcher.

`make_train_step` builds the jit-able (params, opt, tokens) -> step
function used both by the multi-pod dry-run (lower/compile only) and by
the runnable small-scale CLI below (CPU, reduced configs):

    PYTHONPATH=src python -m repro.launch.train --arch granite-moe-1b-a400m \
        --reduced --steps 50 --batch 8 --seq 128
"""
from __future__ import annotations

import argparse
import functools
import time
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, XSharePolicy
from repro.models import init_params, loss_fn
from repro.models.moe import OFF
from repro.optim import adamw_init, adamw_update, clip_by_global_norm, \
    cosine_schedule


def make_train_step(cfg: ArchConfig, *, policy: XSharePolicy = OFF,
                    lr=None, remat: bool = True,
                    capacity_factor: float = 1.25,
                    weight_decay: float = 0.1, clip: float = 1.0,
                    accum_steps: int = 1):
    """fwd+bwd+AdamW step. accum_steps > 1 scans microbatches with f32
    gradient accumulation — activation memory scales with the microbatch
    while the optimizer sees the full global batch (required to fit the
    235B-class train shapes on 16GB/chip)."""
    lr = lr or cosine_schedule(3e-4, 100, 10000)

    def grad_of(p, tokens, prefix_embeds):
        def lf(p):
            loss, aux = loss_fn(cfg, p, tokens,
                                prefix_embeds=prefix_embeds,
                                policy=policy, remat=remat,
                                capacity_factor=capacity_factor)
            return loss, aux
        (loss, _), grads = jax.value_and_grad(lf, has_aux=True)(p)
        return loss, grads

    def train_step(params, opt_state, tokens, prefix_embeds=None):
        if accum_steps == 1:
            loss, grads = grad_of(params, tokens, prefix_embeds)
        else:
            B = tokens.shape[0]
            assert B % accum_steps == 0, (B, accum_steps)
            mb = B // accum_steps
            tok_mb = tokens.reshape((accum_steps, mb) + tokens.shape[1:])
            pe_mb = None
            if prefix_embeds is not None:
                pe_mb = prefix_embeds.reshape(
                    (accum_steps, mb) + prefix_embeds.shape[1:])

            def micro(carry, xs):
                g_acc, l_acc = carry
                t = xs[0]
                pe = xs[1] if pe_mb is not None else None
                loss, g = grad_of(params, t, pe)
                g_acc = jax.tree_util.tree_map(
                    lambda a, b: a + jnp.asarray(b, jnp.float32),
                    g_acc, g)
                return (g_acc, l_acc + loss), None

            g0 = jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            xs = (tok_mb,) if pe_mb is None else (tok_mb, pe_mb)
            (g_acc, l_acc), _ = jax.lax.scan(micro, (g0, jnp.zeros(())),
                                             xs)
            grads = jax.tree_util.tree_map(lambda g: g / accum_steps,
                                           g_acc)
            loss = l_acc / accum_steps
        grads, gnorm = clip_by_global_norm(grads, clip)
        params, opt_state = adamw_update(grads, opt_state, params, lr=lr,
                                         weight_decay=weight_decay)
        return params, opt_state, {"loss": loss, "grad_norm": gnorm}

    return train_step


def main(argv: Optional[list] = None) -> None:
    from repro.configs.registry import get_config
    from repro.data import SyntheticLM, batches

    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--ckpt", type=str, default=None)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    key = jax.random.PRNGKey(args.seed)
    params = init_params(cfg, key)
    opt = adamw_init(params)
    step_fn = jax.jit(make_train_step(
        cfg, lr=cosine_schedule(args.lr, 10, args.steps), remat=False))

    lm = SyntheticLM(cfg.vocab_size, name=args.arch)
    stream = batches(lm, batch=args.batch, seq_len=args.seq,
                     seed=args.seed,
                     num_codebooks=(cfg.num_codebooks
                                    if cfg.family == "audio" else 1))
    prefix = None
    if cfg.prefix_len:
        prefix = jax.random.normal(
            key, (args.batch, cfg.prefix_len, cfg.d_model))

    t0 = time.perf_counter()
    for step in range(args.steps):
        tokens = jnp.asarray(next(stream))
        params, opt, m = step_fn(params, opt, tokens, prefix)
        if step % max(1, args.steps // 10) == 0 or step == args.steps - 1:
            print(f"step {step:4d} loss {float(m['loss']):.4f} "
                  f"gnorm {float(m['grad_norm']):.3f}")
    dt = time.perf_counter() - t0
    print(f"done: {args.steps} steps in {dt:.1f}s "
          f"({args.steps * args.batch * args.seq / dt:.0f} tok/s)")
    if args.ckpt:
        from repro.checkpoint import save_checkpoint
        save_checkpoint(args.ckpt, params, step=args.steps)
        print("saved", args.ckpt)


if __name__ == "__main__":
    main()
