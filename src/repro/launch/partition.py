"""Partitioning rules: param / optimizer / cache / activation
PartitionSpecs per architecture, by tree-path pattern (t5x-style).

Scheme (see DESIGN.md §7):
  * "model" axis (16-way): tensor parallel for dense projections
    (heads / d_ff / vocab), EXPERT parallel for MoE expert weights —
    the paper's G GPU groups == contiguous expert ranges per model-axis
    shard, so Alg 5/6's MaxLoad is the per-shard activated-expert count.
  * "data" (and "pod") axes: batch for train/prefill/decode; for
    batch-1 long-context decode the cache sequence axis takes the spare
    axes instead (flash-decode with cross-shard softmax reduction).
"""
from __future__ import annotations

import math
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ArchConfig, ShapeConfig
from repro.models import ssm as S


def _path_str(path) -> str:
    parts = []
    for p in path:
        parts.append(str(getattr(p, "key", getattr(p, "idx", p))))
    return "/".join(parts)


def batch_axes(mesh, batch: int) -> Tuple[str, ...]:
    """Largest prefix of (pod, data) whose size divides the batch."""
    axes = [a for a in ("pod", "data") if a in mesh.shape]
    for k in range(len(axes), -1, -1):
        cand = tuple(axes[:k])
        size = math.prod(mesh.shape[a] for a in cand) if cand else 1
        if batch % size == 0:
            return cand
    return ()


def seq_axes(mesh, batch: int) -> Tuple[str, ...]:
    """Axes left for the cache sequence dim after batch sharding."""
    used = set(batch_axes(mesh, batch))
    return tuple(a for a in ("pod", "data", "model") if a in mesh.shape
                 and a not in used)


def _axes_or_none(axes: Tuple[str, ...]):
    return axes if axes else None


# ----------------------------------------------------------------- params --

def param_specs(cfg: ArchConfig, mesh, params_tree, *,
                fsdp: bool = True) -> Dict:
    """PartitionSpec pytree matching params (pass eval_shape output).

    2D "FSDP x TP" sharding: every large matrix shards its parallel
    dimension (heads / d_ff / experts / vocab) over "model" and its
    other big dimension (usually d_model) over "data" — so parameter +
    optimizer memory scales with the FULL chip count, while the "model"
    axis still carries the tensor/expert-parallel compute layout (XLA
    inserts the per-layer all-gathers, i.e. ZeRO-3 semantics).
    """
    msize = mesh.shape["model"]
    dsize = mesh.shape["data"]
    a = cfg.attn

    def divides(n: int) -> bool:
        return n > 0 and n % msize == 0

    def _fsdp(n: int):
        """'data' if FSDP is on and the dim divides the data axis."""
        return "data" if (fsdp and n % dsize == 0) else None

    def spec2(shape, model_pos, data_pos):
        nd = len(shape)
        dims = [None] * nd
        if model_pos is not None:
            dims[model_pos] = "model"
        if data_pos is not None:
            dims[data_pos] = _fsdp(shape[data_pos])
        return P(*dims)

    def rule(path, leaf):
        s = _path_str(path)
        sh = leaf.shape
        nd = len(sh)
        if s.endswith("embed"):
            if cfg.family == "audio":
                return spec2(sh, 1, 2)            # (K, V, d)
            return spec2(sh, 0, 1)                # (V, d)
        if s.endswith("lm_head"):
            return spec2(sh, nd - 1, nd - 2)      # (..., d, V)
        if "/attn/" in s:
            if s.endswith("wq"):
                return spec2(sh, nd - 1 if divides(a.num_heads) else None,
                             nd - 2)
            if s.endswith("wk") or s.endswith("wv"):
                return spec2(sh,
                             nd - 1 if divides(a.num_kv_heads) else None,
                             nd - 2)
            if s.endswith("wo"):
                return spec2(sh, nd - 2 if divides(a.num_heads) else None,
                             nd - 1)
            return P()                            # q_norm / k_norm
        if "/moe/" in s:
            if s.endswith("wg"):
                return P()                        # router replicated
            if s[-3:] in ("ws1", "ws3"):
                return spec2(sh, nd - 1, nd - 2)
            if s.endswith("ws2"):
                return spec2(sh, nd - 2, nd - 1)
            # expert weights (L, E, d, f) / (L, E, f, d): experts over
            # "model" (the paper's EP groups), d_ff over "data" (FSDP)
            fpos = nd - 1 if s.endswith("w1") or s.endswith("w3") \
                else nd - 2
            return P(*[("model" if j == nd - 3 else
                        ("data" if fsdp and j == fpos
                         and sh[fpos] % dsize == 0
                         else None)) for j in range(nd)])
        if "/mlp/" in s:
            mp = (nd - 1) if not s.endswith("w2") else (nd - 2)
            op = (nd - 2) if not s.endswith("w2") else (nd - 1)
            return spec2(sh, mp if divides(cfg.d_ff) else None, op)
        if "/ssm/" in s:
            if s.endswith("in_z") or s.endswith("in_x"):
                return spec2(sh, nd - 1, nd - 2)
            if s.endswith("in_dt"):
                d_inner = cfg.ssm.expand * cfg.d_model
                nh = d_inner // cfg.ssm.head_dim
                return spec2(sh, nd - 1 if divides(nh) else None, nd - 2)
            if s.endswith("in_B") or s.endswith("in_C"):
                return spec2(sh, None, nd - 2)
            if s.endswith("conv_x_w") or s.endswith("conv_x_b") \
                    or s.endswith("norm_w"):
                return P(*([None] * (nd - 1) + ["model"]))
            if s.endswith("out_proj"):
                return spec2(sh, nd - 2, nd - 1)
            return P()                            # conv_B/C, A/D/dt_bias
        return P()                                # norms, biases

    return jax.tree_util.tree_map_with_path(rule, params_tree)


def opt_specs(pspecs) -> Tuple:
    """AdamWState(step, mu, nu) specs mirroring param specs."""
    from repro.optim.adamw import AdamWState
    return AdamWState(step=P(), mu=pspecs,
                      nu=jax.tree_util.tree_map(lambda s: s, pspecs))


# ------------------------------------------------------------------ cache --

def cache_specs(cfg: ArchConfig, mesh, batch: int) -> Dict:
    ba = _axes_or_none(batch_axes(mesh, batch))
    sa = _axes_or_none(seq_axes(mesh, batch))
    if batch > 1:
        sa = "model" if "model" in mesh.shape else None
    specs: Dict = {"cur_len": P()}
    msize = mesh.shape.get("model", 1)

    def kv_spec():
        # prefer HEAD sharding when kv_heads divides the model axis:
        # attention stays fully shard-local (no distributed softmax, no
        # scatter into a sharded sequence dim); fall back to sequence
        # sharding for small-kv GQA/MQA caches.
        if cfg.attn and cfg.attn.num_kv_heads % msize == 0 and batch > 1:
            return P(None, ba, None, "model", None)
        return P(None, ba, sa, None, None)

    if cfg.family in ("dense", "vlm", "audio", "moe"):
        specs["kv_k"] = kv_spec()
        specs["kv_v"] = kv_spec()
    if cfg.family in ("ssm", "hybrid"):
        msize = mesh.shape["model"]
        _, nh, _ = S.dims(cfg.ssm, cfg.d_model)
        head_ax = "model" if nh % msize == 0 else None
        specs["conv_x"] = P(None, ba, None, "model")
        specs["conv_B"] = P(None, ba, None, None)
        specs["conv_C"] = P(None, ba, None, None)
        specs["state"] = P(None, ba, head_ax, None, None)
    if cfg.family == "hybrid":
        specs["shared_k"] = kv_spec()
        specs["shared_v"] = kv_spec()
    return specs


# ------------------------------------------------------------- activations --

def token_spec(cfg: ArchConfig, mesh, batch: int) -> P:
    ba = _axes_or_none(batch_axes(mesh, batch))
    if cfg.family == "audio":
        return P(ba, None, None)
    return P(ba, None)


def logits_spec(cfg: ArchConfig, mesh, batch: int, *, with_seq: bool) -> P:
    ba = _axes_or_none(batch_axes(mesh, batch))
    dims = [ba] + ([None] if with_seq else [])
    if cfg.family == "audio":
        dims.append(None)                         # codebook axis
    dims.append("model")                          # padded vocab
    return P(*dims)


def prefix_spec(cfg: ArchConfig, mesh, batch: int) -> P:
    ba = _axes_or_none(batch_axes(mesh, batch))
    return P(ba, None, None)


def named(mesh, spec_tree):
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s), spec_tree,
        is_leaf=lambda x: isinstance(x, P))
