"""Roofline-term derivation from compiled dry-run artifacts.

Hardware constants: TPU v5e — 197 TFLOP/s bf16 per chip, 819 GB/s HBM,
~50 GB/s per ICI link. cost_analysis() of an SPMD-compiled module reports
PER-DEVICE flops / bytes; collective bytes are parsed from the compiled
HLO (also per-device shard sizes). So:

  compute   = flops_per_device / PEAK
  memory    = bytes_per_device / HBM_BW
  collective= collective_bytes_per_device / ICI_BW
"""
from __future__ import annotations

import re
from typing import Dict, Optional

from repro.configs.base import ArchConfig, ShapeConfig

PEAK_FLOPS = 197e12      # bf16 FLOP/s per v5e chip
HBM_BW = 819e9           # bytes/s per chip
ICI_BW = 50e9            # bytes/s per link

_DTYPE_BYTES = {"f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3": 1,
                "f8e5m2": 1, "s64": 8, "u64": 8, "s32": 4, "u32": 4,
                "s16": 2, "u16": 2, "s8": 1, "u8": 1, "pred": 1,
                "c64": 8, "c128": 16}

COLLECTIVE_OPS = ("all-reduce", "all-gather", "reduce-scatter",
                  "all-to-all", "collective-permute")

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Sum output-shape bytes of every collective op, per op kind.

    (Output size == shard-level bytes moved through ICI for AG/AR/RS/A2A
    up to small constant factors; good enough for a roofline term.)
    """
    out = {k: 0 for k in COLLECTIVE_OPS}
    for line in hlo_text.splitlines():
        ls = line.strip()
        for op in COLLECTIVE_OPS:
            # "%x = TYPE op-name(" with optional -start/-done variants
            m = re.search(r"=\s+(.*?)\s+" + op + r"(-start)?\(", ls)
            if m:
                out[op] += _shape_bytes(m.group(1))
                break
    return out


def collective_bytes_split(hlo_text: str) -> Dict[str, int]:
    """Collective bytes split by position: inside while-loop bodies
    (replayed once per trip — for our programs, the layer scan) vs
    outside (executed once). XLA's cost analysis counts loop bodies
    once, so the §Roofline collective term scales the inside share by
    the known layer trip count."""
    # map: computation name -> list of (op, bytes)
    comp = None
    per_comp: Dict[str, list] = {}
    bodies = set()
    for line in hlo_text.splitlines():
        ls = line.strip()
        m = re.match(r"(?:ENTRY\s+)?%?([\w.\-]+)\s*\([^)]*\)\s*->.*{", ls)
        if m and "=" not in ls.split("(")[0]:
            comp = m.group(1)
            per_comp.setdefault(comp, [])
            continue
        bm = re.search(r"body=%?([\w.\-]+)", ls)
        if bm:
            bodies.add(bm.group(1))
        for op in COLLECTIVE_OPS:
            mm = re.search(r"=\s+(.*?)\s+" + op + r"(-start)?\(", ls)
            if mm and comp is not None:
                per_comp[comp].append((op, _shape_bytes(mm.group(1))))
                break
    inside = sum(b for c in bodies for _, b in per_comp.get(c, []))
    total = sum(b for items in per_comp.values() for _, b in items)
    return {"inside": inside, "outside": total - inside}


def roofline_terms(flops: float, bytes_accessed: float,
                   coll_bytes: float) -> Dict[str, float]:
    t_c = flops / PEAK_FLOPS
    t_m = bytes_accessed / HBM_BW
    t_x = coll_bytes / ICI_BW
    dom = max(("compute", t_c), ("memory", t_m), ("collective", t_x),
              key=lambda kv: kv[1])[0]
    return {"compute_s": t_c, "memory_s": t_m, "collective_s": t_x,
            "dominant": dom}


# ------------------------------------------------------- model FLOPs ------

def param_counts(cfg: ArchConfig) -> Dict[str, float]:
    """Analytic parameter counts: total and per-token-active (MoE)."""
    d, L, V = cfg.d_model, cfg.num_layers, cfg.padded_vocab
    emb = V * d * (cfg.num_codebooks if cfg.family == "audio" else 1)
    head = 0 if cfg.tie_embeddings else emb
    per_layer_active = 0.0
    per_layer_total = 0.0
    if cfg.has_attention and cfg.family not in ("ssm", "hybrid"):
        a = cfg.attn
        attn = d * a.num_heads * a.head_dim * 2 \
            + d * a.num_kv_heads * a.head_dim * 2
        per_layer_total += attn
        per_layer_active += attn
    if cfg.family in ("ssm", "hybrid") and cfg.ssm:
        di, nh, dbc = (cfg.ssm.expand * d,
                       cfg.ssm.expand * d // cfg.ssm.head_dim,
                       cfg.ssm.n_groups * cfg.ssm.d_state)
        ssm = d * di * 2 + d * dbc * 2 + d * nh + di * d
        per_layer_total += ssm
        per_layer_active += ssm
    if cfg.has_moe:
        e = cfg.moe
        expert = 3 * d * e.d_ff_expert
        per_layer_total += e.num_experts * expert + d * e.num_experts
        per_layer_active += e.top_k * expert + d * e.num_experts
        if e.num_shared_experts:
            sh = 3 * d * e.d_ff_shared * e.num_shared_experts
            per_layer_total += sh
            per_layer_active += sh
    elif cfg.d_ff:
        mlp = (3 if cfg.act == "swiglu" else 2) * d * cfg.d_ff
        per_layer_total += mlp
        per_layer_active += mlp
    shared_attn = 0
    if cfg.family == "hybrid" and cfg.attn:
        a = cfg.attn
        shared_attn = d * a.num_heads * a.head_dim * 2 \
            + d * a.num_kv_heads * a.head_dim * 2 \
            + 3 * d * cfg.d_ff
    total = emb + head + L * per_layer_total + shared_attn
    active = emb + head + L * per_layer_active + shared_attn
    return {"total": total, "active": active}


def model_flops(cfg: ArchConfig, shape: ShapeConfig) -> float:
    """Global MODEL_FLOPS = 6*N*D (6*N_active*D for MoE), D = tokens
    processed by the step (train counts fwd+bwd via the 6x factor;
    prefill uses 2*N*D, decode 2*N_active*B)."""
    counts = param_counts(cfg)
    n = counts["active"]
    if shape.kind == "train":
        return 6.0 * n * shape.global_batch * shape.seq_len
    if shape.kind == "prefill":
        return 2.0 * n * shape.global_batch * shape.seq_len
    return 2.0 * n * shape.global_batch        # one decode step


# ----------------------------------------------- analytic step costs ------
#
# XLA's cost_analysis counts a while-loop BODY ONCE (verified: a 10-step
# scan of an NxN matmul reports 1/10 of the true FLOPs), so for scanned
# models the HLO numbers undercount by the trip counts. The roofline
# therefore uses closed-form per-step costs derived from the model
# structure we compiled (exact trip counts are ours by construction),
# with the compiled HLO contributing the memory analysis and the
# collective INVENTORY (scaled by the layer-loop trip count).

BYTES_W = 2          # bf16 weights/cache


def expected_selected(E: int, k: int, B_tokens: int, policy) -> float:
    """Expected |selected expert set| per layer under a policy.

    Baseline (off): the paper's E[N_a] = E(1-(1-k/E)^B).
    batch:  warm-up E(1-(1-k0/E)^B) + m_l, capped by baseline.
    spec:   per-request warm-up/budgets union, capped similarly.
    ep:     m_g per group * 16 groups (the mesh model extent).
    """
    base = E * (1 - (1 - k / E) ** B_tokens)
    m = policy.mode
    if m == "off":
        return base
    if m == "batch":
        warm = E * (1 - (1 - min(policy.k0, E) / E) ** B_tokens) \
            if policy.k0 else 0.0
        return min(base, warm + policy.m_l)
    if m == "spec":
        warm = E * (1 - (1 - min(policy.k0, E) / E) ** B_tokens) \
            if policy.k0 else 0.0
        b = max(1, B_tokens // 4)
        return min(base, warm + b * policy.m_r + policy.m_l)
    if m == "ep":
        return min(base, policy.m_g * 16)
    return base


def bottleneck_shard_load(selected: float, shards: int, policy) -> float:
    """Expected MAX experts on one model-axis shard. EP-aware selection
    bounds it at m_g by construction; otherwise balanced-binomial mean +
    ~2 sigma imbalance."""
    if policy is not None and policy.mode == "ep":
        return float(policy.m_g)
    mean = selected / shards
    return min(mean + 2.0 * (mean ** 0.5) + 1.0, selected)


def _attn_flops(cfg, tokens: int, ctx: float) -> float:
    a = cfg.attn
    d, dh = cfg.d_model, a.head_dim
    proj = 2 * d * dh * (2 * a.num_heads + 2 * a.num_kv_heads)
    attn = 4 * ctx * a.num_heads * dh
    return tokens * (proj + attn)


def _ssm_flops(cfg, tokens: int, decode: bool) -> float:
    s = cfg.ssm
    d = cfg.d_model
    d_inner = s.expand * d
    nh = d_inner // s.head_dim
    dbc = s.n_groups * s.d_state
    proj = 2 * d * (2 * d_inner + 2 * dbc + nh) + 2 * d_inner * d
    if decode:
        scan = nh * 4 * s.d_state * s.head_dim
    else:
        l = s.chunk_size
        scan = nh * (2 * l * s.d_state + 2 * l * s.head_dim
                     + 4 * s.d_state * s.head_dim)
    conv = 2 * s.d_conv * (d_inner + 2 * dbc)
    return tokens * (proj + scan + conv)


def _ffn_flops(cfg, tokens: int) -> float:
    d = cfg.d_model
    if cfg.has_moe:
        e = cfg.moe
        f = 2 * d * e.num_experts + 6 * d * e.d_ff_expert * e.top_k
        if e.num_shared_experts:
            f += 6 * d * e.d_ff_shared * e.num_shared_experts
        return tokens * f
    mult = 6 if cfg.act == "swiglu" else 4
    return tokens * mult * d * cfg.d_ff


def analytic_flops(cfg: ArchConfig, shape: ShapeConfig,
                   window: Optional[int] = None) -> float:
    """Global FLOPs for one step (fwd only; train multiplies below)."""
    B = shape.global_batch
    if shape.kind == "decode":
        tokens = B
        ctx = min(window, shape.cache_len) if window else shape.cache_len
    else:
        tokens = B * shape.seq_len
        eff = min(window, shape.seq_len) if window else shape.seq_len
        ctx = eff / 2                      # mean causal context
    total = 0.0
    L = cfg.num_layers
    if cfg.family in ("dense", "vlm", "audio", "moe"):
        total += L * (_attn_flops(cfg, tokens, ctx)
                      + _ffn_flops(cfg, tokens))
    elif cfg.family == "ssm":
        total += L * _ssm_flops(cfg, tokens, shape.kind == "decode")
    elif cfg.family == "hybrid":
        total += L * _ssm_flops(cfg, tokens, shape.kind == "decode")
        n_app = -(-L // cfg.attn_every)
        total += n_app * (_attn_flops(cfg, tokens, ctx)
                          + tokens * 6 * cfg.d_model * cfg.d_ff)
    head = 2 * cfg.d_model * cfg.padded_vocab
    total += tokens * head if shape.kind != "decode" else B * head
    if shape.kind == "train":
        total *= 4.0   # bwd = 2x fwd, remat recompute = +1x fwd
    return total


def analytic_bytes(cfg: ArchConfig, shape: ShapeConfig, *,
                   window: Optional[int] = None,
                   policy=None, num_devices: int = 256,
                   cache_bytes_per_el: int = BYTES_W) -> float:
    """Global-equivalent HBM bytes for one step.

    Decode uses BOTTLENECK-SHARD accounting for MoE expert weights (the
    paper's Sec 5 insight: the layer waits for the hottest expert
    shard), expressed as bottleneck-per-device * num_devices so the
    caller's /num_devices yields the bottleneck device's traffic.
    """
    counts = param_counts(cfg)
    B = shape.global_batch
    d = cfg.d_model
    if shape.kind == "decode":
        w_bytes = counts["total"] * BYTES_W
        if cfg.has_moe:
            e = cfg.moe
            per_exp = 3 * d * e.d_ff_expert
            pol = policy
            if pol is None:
                from repro.configs.base import XSharePolicy
                pol = XSharePolicy(mode="off")
            sel = expected_selected(e.num_experts, e.top_k, B, pol)
            shards = min(16, e.num_experts)
            bottleneck = bottleneck_shard_load(sel, shards, pol)
            # remove all expert weights, add bottleneck-shard load
            # scaled to a global-equivalent figure
            w_bytes -= cfg.num_layers * per_exp * e.num_experts * BYTES_W
            w_bytes += cfg.num_layers * per_exp * bottleneck * shards \
                * BYTES_W
        cache = _cache_bytes(cfg, shape, window) \
            * cache_bytes_per_el / BYTES_W
        return w_bytes + cache * (1 + 2 / max(shape.cache_len, 1)) \
            + B * d * cfg.num_layers * 8 * BYTES_W
    tokens = B * shape.seq_len
    act_traffic = tokens * d * 8 * BYTES_W * cfg.num_layers
    if cfg.has_attention and cfg.family not in ("ssm",):
        nq = max(1, shape.seq_len // 512)
        kv_stream = tokens * cfg.attn.num_kv_heads * cfg.attn.head_dim \
            * 2 * BYTES_W * min(nq, 8) * cfg.num_layers
        act_traffic += kv_stream
    w_bytes = counts["total"] * BYTES_W
    if shape.kind == "train":
        # params read fwd+bwd(+remat) + grad write + AdamW state r/w f32
        w_bytes = counts["total"] * (3 * BYTES_W + BYTES_W + 4 * 4)
        act_traffic *= 3
    return w_bytes + act_traffic


def _cache_bytes(cfg: ArchConfig, shape: ShapeConfig,
                 window: Optional[int]) -> float:
    B = shape.global_batch
    total = 0.0
    if cfg.has_attention and cfg.family not in ("ssm", "hybrid"):
        C = (window + 512) if window else shape.cache_len
        a = cfg.attn
        total += cfg.num_layers * B * C * a.num_kv_heads * a.head_dim \
            * 2 * BYTES_W
    if cfg.family in ("ssm", "hybrid"):
        s = cfg.ssm
        d_inner = s.expand * cfg.d_model
        nh = d_inner // s.head_dim
        total += cfg.num_layers * B * nh * s.head_dim * s.d_state * 4
    if cfg.family == "hybrid":
        a = cfg.attn
        n_app = -(-cfg.num_layers // cfg.attn_every)
        total += n_app * B * shape.cache_len * a.num_kv_heads \
            * a.head_dim * 2 * BYTES_W
    return total


def step_terms(record: Dict, num_devices: int,
               cfg: Optional[ArchConfig] = None,
               shape: Optional[ShapeConfig] = None,
               window: Optional[int] = None,
               accum: int = 1, policy=None,
               cache_bytes_per_el: int = BYTES_W) -> Dict:
    """Assemble the §Roofline row: analytic compute/memory terms +
    HLO-inventory collectives scaled by the layer trip count."""
    row: Dict = {"hlo_flops_per_device_raw": record["flops_per_device"],
                 "hlo_bytes_per_device_raw": record["bytes_per_device"]}
    if cfg is None or shape is None:
        row.update(roofline_terms(record["flops_per_device"],
                                  record["bytes_per_device"],
                                  record["collective_bytes_per_device"]))
        return row
    flops_g = analytic_flops(cfg, shape, window)
    bytes_g = analytic_bytes(cfg, shape, window=window, policy=policy,
                             num_devices=num_devices,
                             cache_bytes_per_el=cache_bytes_per_el)
    # collectives parsed from HLO count loop bodies once; the layer scan
    # replays the inside-loop share num_layers times (x accum for train)
    trips = cfg.num_layers * accum
    if "collective_bytes_inside_loop" in record:
        coll = (record["collective_bytes_inside_loop"] * trips
                + record["collective_bytes_outside_loop"])
    else:
        coll = record["collective_bytes_per_device"] * trips
    terms = roofline_terms(flops_g / num_devices, bytes_g / num_devices,
                           coll)
    row.update(terms)
    row["analytic_flops_global"] = flops_g
    row["analytic_bytes_global"] = bytes_g
    row["collective_trip_correction"] = trips
    mf = model_flops(cfg, shape)
    row["model_flops"] = mf
    row["useful_ratio"] = mf / max(flops_g, 1.0)
    return row
