"""Serving step factories (used by the dry-run) + runnable CLI demo.

    PYTHONPATH=src python -m repro.launch.serve --arch granite-moe-1b-a400m \
        --reduced --batch 4 --prompt-len 32 --new-tokens 32 \
        --policy batch --m-l 8 --k0 1
"""
from __future__ import annotations

import argparse
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, ShapeConfig, XSharePolicy
from repro.models import decode_step, prefill
from repro.models.moe import OFF


def make_prefill(cfg: ArchConfig, *, cache_len: int,
                 force_window: Optional[int] = None,
                 capacity_factor: float = 2.0):
    def fn(params, tokens, prefix_embeds=None):
        return prefill(cfg, params, tokens, cache_len=cache_len,
                       prefix_embeds=prefix_embeds,
                       force_window=force_window,
                       capacity_factor=capacity_factor)
    return fn


def make_serve_step(cfg: ArchConfig, *, policy: XSharePolicy = OFF,
                    force_window: Optional[int] = None,
                    capacity_factor: float = 2.0):
    """One decode step: T=1 new token against the cache."""
    def fn(params, tokens, cache):
        return decode_step(cfg, params, tokens, cache, policy=policy,
                           force_window=force_window,
                           capacity_factor=capacity_factor)
    return fn


def main(argv=None) -> None:
    import numpy as np
    from repro.configs.registry import get_config
    from repro.data import SyntheticLM
    from repro.models import init_params
    from repro.serving import Engine

    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--new-tokens", type=int, default=32)
    ap.add_argument("--policy", default="off",
                    choices=["off", "batch", "spec", "ep"])
    ap.add_argument("--k0", type=int, default=1)
    ap.add_argument("--m-l", type=int, default=8)
    ap.add_argument("--m-r", type=int, default=4)
    ap.add_argument("--m-g", type=int, default=4)
    ap.add_argument("--spec-len", type=int, default=0)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    key = jax.random.PRNGKey(args.seed)
    params = init_params(cfg, key)
    policy = XSharePolicy(mode=args.policy, k0=args.k0, m_l=args.m_l,
                          m_r=args.m_r, m_g=args.m_g)
    lm = SyntheticLM(cfg.vocab_size, name=args.arch)
    rng = np.random.default_rng(args.seed)
    prompts = lm.sample(rng, args.batch, args.prompt_len)

    draft = None
    if args.spec_len:
        dcfg = cfg.reduced(num_layers=2, max_d_model=128)
        draft = (dcfg, init_params(dcfg, jax.random.PRNGKey(1)))

    eng = Engine(cfg, params, policy=policy,
                 cache_len=args.prompt_len + args.new_tokens + 16,
                 draft=draft, spec_len=args.spec_len)
    toks, stats = eng.generate(prompts, args.new_tokens)
    print("generated:", toks.shape)
    print(f"OTPS {stats.otps:.1f}  steps {stats.steps}")
    if stats.accepted_hist:
        print(f"mean accepted drafts/step: {stats.mean_accepted:.2f}")
    if stats.layer_aux:
        print(f"mean activated experts/layer: "
              f"{stats.mean_aux('activated_experts'):.2f} "
              f"(selected set {stats.mean_aux('selected_set'):.2f}, "
              f"gate mass {stats.mean_aux('gate_mass'):.3f})")


if __name__ == "__main__":
    main()
