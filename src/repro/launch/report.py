"""Render EXPERIMENTS.md §Dry-run and §Roofline tables from
dryrun_results.json.

    PYTHONPATH=src python -m repro.launch.report dryrun_results.json
"""
from __future__ import annotations

import json
import sys


def fmt_e(x: float) -> str:
    return f"{x:.2e}"


def dryrun_table(records) -> str:
    lines = [
        "| arch | shape | mesh | policy | peak GB (xla-cpu) | analytic "
        "state GB | FLOPs/dev | bytes/dev | coll bytes/dev | "
        "collectives | compile s |",
        "|---|---|---|---|---|---|---|---|---|---|---|",
    ]
    for r in sorted(records, key=lambda r: (r["arch"], r["shape"],
                                            r["mesh"])):
        an = r.get("analytic", {})
        an_s = " + ".join(f"{k[:-3]}={v}" for k, v in an.items())
        colls = ",".join(f"{k.split('-')[0] if '-' not in k else k}:"
                         f"{v/1e6:.0f}M"
                         for k, v in r.get("collectives", {}).items())
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | {r['policy']} "
            f"| {r['peak_hbm_gb']:.2f} | {an_s} "
            f"| {fmt_e(r['flops_per_device'])} "
            f"| {fmt_e(r['bytes_per_device'])} "
            f"| {fmt_e(r['collective_bytes_per_device'])} "
            f"| {colls} | {r['compile_s']} |")
    return "\n".join(lines)


def roofline_table(records) -> str:
    """Single-pod roofline: 3 terms, dominant, MODEL_FLOPS ratio."""
    lines = [
        "| arch | shape | compute s | memory s | collective s | "
        "dominant | MODEL_FLOPS | HLO FLOPs (global) | useful ratio |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for r in sorted(records, key=lambda r: (r["arch"], r["shape"])):
        if r["mesh"] != "16x16":
            continue
        lines.append(
            f"| {r['arch']} | {r['shape']} "
            f"| {fmt_e(r['compute_s'])} | {fmt_e(r['memory_s'])} "
            f"| {fmt_e(r['collective_s'])} | **{r['dominant']}** "
            f"| {fmt_e(r.get('model_flops', 0))} "
            f"| {fmt_e(r.get('hlo_flops_global', 0))} "
            f"| {r.get('useful_ratio', 0):.3f} |")
    return "\n".join(lines)


def main() -> None:
    path = sys.argv[1] if len(sys.argv) > 1 else "dryrun_results.json"
    records = json.load(open(path))
    print("## Dry-run records\n")
    print(dryrun_table(records))
    print("\n## Roofline (single-pod 16x16)\n")
    print(roofline_table(records))


if __name__ == "__main__":
    main()
