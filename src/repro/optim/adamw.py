"""AdamW + schedules, pytree-native (optimizer state shards like params)."""
from __future__ import annotations

from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jnp.ndarray
    mu: object
    nu: object


def adamw_init(params) -> AdamWState:
    zeros = jax.tree_util.tree_map(
        lambda p: jnp.zeros_like(p, dtype=jnp.float32), params)
    return AdamWState(step=jnp.zeros((), jnp.int32), mu=zeros,
                      nu=jax.tree_util.tree_map(jnp.copy, zeros))


def adamw_update(grads, state: AdamWState, params, *, lr,
                 b1: float = 0.9, b2: float = 0.95, eps: float = 1e-8,
                 weight_decay: float = 0.1):
    step = state.step + 1
    lr_t = lr(step) if callable(lr) else lr

    def upd(g, m, v, p):
        g = jnp.asarray(g, jnp.float32)
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * g * g
        mhat = m / (1 - b1 ** step.astype(jnp.float32))
        vhat = v / (1 - b2 ** step.astype(jnp.float32))
        pf = jnp.asarray(p, jnp.float32)
        new_p = pf - lr_t * (mhat / (jnp.sqrt(vhat) + eps)
                             + weight_decay * pf)
        return new_p.astype(p.dtype), m, v

    flat_p, treedef = jax.tree_util.tree_flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state.mu)
    flat_v = treedef.flatten_up_to(state.nu)
    out = [upd(g, m, v, p)
           for g, m, v, p in zip(flat_g, flat_m, flat_v, flat_p)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    return new_p, AdamWState(step=step, mu=new_m, nu=new_v)


def cosine_schedule(base_lr: float, warmup: int, total: int
                    ) -> Callable[[jnp.ndarray], jnp.ndarray]:
    def sched(step):
        step = step.astype(jnp.float32)
        warm = base_lr * step / max(warmup, 1)
        frac = jnp.clip((step - warmup) / max(total - warmup, 1), 0.0, 1.0)
        cos = base_lr * 0.5 * (1 + jnp.cos(jnp.pi * frac))
        return jnp.where(step < warmup, warm, cos)
    return sched


def clip_by_global_norm(grads, max_norm: float):
    leaves = jax.tree_util.tree_leaves(grads)
    gn = jnp.sqrt(sum(jnp.sum(jnp.square(jnp.asarray(g, jnp.float32)))
                      for g in leaves))
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gn, 1e-9))
    return jax.tree_util.tree_map(lambda g: g * scale, grads), gn
