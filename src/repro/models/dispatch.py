"""Sort-based grouped expert dispatch — the capacity-free MoE hot path.

The GShard einsum dispatch in ``models/moe.py`` materializes
``(G, t, E, C)`` one-hot dispatch/combine tensors and runs every expert
at fixed capacity C, so both compute and memory scale with E even when
XShare has shrunk the routed set to a handful of experts. This module
replaces that with the sort/scatter pipeline used by modern MoE
inference stacks (MegaBlocks-style grouped GEMM):

  1. flatten the (T, k) token-expert assignments to N = T*k pairs and
     argsort them by expert id (stable, so within an expert tokens stay
     in batch order and an optional capacity clamp keeps the *first*
     tokens — GShard drop semantics);
  2. bincount + exclusive cumsum give per-expert segment offsets; each
     segment is padded to a multiple of ``block_t`` so every row tile
     belongs to exactly one expert;
  3. gather token rows into that expert-contiguous padded layout and
     run a grouped GEMM over the occupied tiles — either the Pallas
     ``kernels.moe_ffn.grouped_ffn`` kernel (compiled on TPU; weight
     blocks are DMA'd per occupied tile via scalar-prefetched tile
     expert ids) or a pure-jnp tile-gather einsum with identical
     layout semantics (the CPU / interpret-free fallback);
  4. scatter-combine the per-row FFN outputs back to token order with
     the gate weights — an (N,)-indexed scatter-add, not a (T, E, C)
     einsum.

Everything is shape-static under jit: the padded row buffer is sized
for the worst case (every occupied expert wastes block_t - 1 rows) and
unoccupied tail tiles are masked via ``tile_valid``.

Expert-parallel note: tiles are expert-contiguous and experts shard
contiguously over the mesh "model" axis, so constraining the *tile*
axis over "model" places each expert group's segments on its own
shard; per-shard load is the group's real segment sizes (see
``group_token_loads``), not E/G * C capacity padding.
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.sharding import constrain, current_mesh, model_axis_size


def _round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


def default_block_t(num_pairs: int, num_experts: int) -> int:
    """Row-tile size: ~half the mean segment length, power of two,
    clamped to [8, 256] (MXU sublane-friendly without exploding the
    padded buffer when segments are ragged)."""
    target = max(8, num_pairs // (2 * num_experts))
    bt = 8
    while bt * 2 <= min(target, 256):
        bt *= 2
    return bt


class DispatchPlan(NamedTuple):
    """Static-shape sorted-dispatch layout for one (T, k, E) routing.

    All arrays are jnp; P (padded rows) and block_t are Python ints
    baked into the trace.
    """
    order: jnp.ndarray       # (N,) argsort of pairs by expert id
    s_tok: jnp.ndarray       # (N,) token index of each sorted pair
    s_w: jnp.ndarray         # (N,) gate weight (0 for dropped pairs)
    dest: jnp.ndarray        # (N,) padded-row index (P => dropped)
    counts: jnp.ndarray      # (E,) real per-expert segment sizes
    tile_eid: jnp.ndarray    # (P/block_t,) owning expert per row tile
    tile_valid: jnp.ndarray  # (P/block_t,) 1 = tile holds real rows
    block_t: int
    padded_rows: int         # P


def dispatch_plan(idx: jnp.ndarray, w: jnp.ndarray, num_experts: int, *,
                  block_t: Optional[int] = None,
                  capacity: Optional[int] = None,
                  max_active: Optional[int] = None,
                  pad_shards: Optional[int] = None) -> DispatchPlan:
    """Build the sorted grouped-dispatch layout.

    idx/w: (T, k) routing decisions; idx == -1 (masked continuous-
    batching slots) and w == 0 pairs are dropped — they consume no rows,
    no tiles, and no expert-weight traffic. capacity: optional per-
    expert clamp (tokens beyond it are dropped, first-in-batch kept —
    the EP load bound); None = capacity-free. max_active: static bound
    on the number of occupied experts (XShare budget) — shrinks the
    padded buffer and tile count, i.e. the thing weight traffic scales
    with. pad_shards: explicit tile-axis divisibility (EP shard count);
    None consults the ambient mesh context — the shard_map executor
    passes 1 because its per-shard plans must not inherit the outer
    mesh's padding.
    """
    T, k = idx.shape
    E = num_experts
    N = T * k
    bt = default_block_t(N, E) if block_t is None else block_t
    occ_bound = min(E, N) if max_active is None else min(max_active, E, N)
    P = _round_up(N + occ_bound * (bt - 1), bt)
    if pad_shards is None:
        pad_shards = model_axis_size() if current_mesh() is not None else 1
    if pad_shards > 1:
        # keep the tile axis divisible by the model axis so the sorted
        # layout can shard over it (EP)
        P = _round_up(P, bt * pad_shards)
    num_tiles = P // bt

    flat_e = idx.reshape(N).astype(jnp.int32)
    flat_w = w.reshape(N).astype(jnp.float32)
    tok = jnp.arange(N, dtype=jnp.int32) // k
    live = (flat_e >= 0) & (flat_e < E) & (flat_w != 0.0)
    key = jnp.where(live, flat_e, E)          # sentinel E sorts last

    order = jnp.argsort(key)                  # stable: batch order kept
    s_e = key[order]
    s_w = jnp.where(live[order], flat_w[order], 0.0)
    s_tok = tok[order]

    raw_counts = jnp.zeros((E,), jnp.int32).at[key].add(1, mode="drop")
    counts = raw_counts if capacity is None else \
        jnp.minimum(raw_counts, capacity)
    # raw segment starts give each sorted row its within-expert rank;
    # the clamp drops the rank >= capacity tail, so kept rows keep
    # contiguous ranks 0..counts-1 and dest needs no re-compaction
    raw_start = jnp.cumsum(raw_counts) - raw_counts
    e_clip = jnp.clip(s_e, 0, E - 1)
    rank = jnp.arange(N, dtype=jnp.int32) - raw_start[e_clip]
    kept = (s_e < E) & (rank < counts[e_clip])
    s_w = jnp.where(kept, s_w, 0.0)

    pad_counts = ((counts + bt - 1) // bt) * bt
    pad_start = jnp.cumsum(pad_counts) - pad_counts
    dest = jnp.where(kept, pad_start[e_clip] + rank, P)

    pad_end = jnp.cumsum(pad_counts)
    tile_start = jnp.arange(num_tiles, dtype=jnp.int32) * bt
    owner = jnp.searchsorted(pad_end, tile_start, side="right")
    tile_valid = (owner < E).astype(jnp.int32)
    # tail tiles point at the FIRST occupied expert (owner[0]), not a
    # clamped E-1: the kernel's weight index maps would otherwise DMA an
    # unrouted last expert's blocks for every padding tile
    fallback = jnp.where(owner[0] < E, owner[0], 0)
    tile_eid = jnp.where(owner < E, owner, fallback).astype(jnp.int32)
    return DispatchPlan(order=order, s_tok=s_tok, s_w=s_w, dest=dest,
                        counts=counts, tile_eid=tile_eid,
                        tile_valid=tile_valid, block_t=bt, padded_rows=P)


def gather_tokens(x: jnp.ndarray, plan: DispatchPlan) -> jnp.ndarray:
    """x: (T, d) -> (P, d) expert-contiguous padded rows (zeros in the
    padding — FFN(0) = 0, so padding never pollutes the combine)."""
    xs = jnp.zeros((plan.padded_rows, x.shape[1]), x.dtype)
    return xs.at[plan.dest].set(x[plan.s_tok], mode="drop")


def grouped_ffn_jnp(xs: jnp.ndarray, w1: jnp.ndarray, w3: jnp.ndarray,
                    w2: jnp.ndarray, plan: DispatchPlan) -> jnp.ndarray:
    """Pure-jnp grouped GEMM over the padded tile layout — identical
    semantics to kernels.moe_ffn.grouped_ffn, XLA-lowered (the fast
    path off-TPU, where the Pallas interpreter would run Python).

    Weight tiles are gathered per row tile (tile_eid), so compute and
    gathered-weight memory scale with occupied tiles (~N/block_t +
    occupied experts), never with E * capacity.
    """
    P, d = xs.shape
    bt = plan.block_t
    nt = P // bt
    xs3 = xs.reshape(nt, bt, d)
    xs3 = constrain(xs3, "model", None, None, tag="ep_sorted")
    w1g = jnp.asarray(w1, jnp.float32)[plan.tile_eid]       # (nt, d, f)
    w3g = jnp.asarray(w3, jnp.float32)[plan.tile_eid]
    w2g = jnp.asarray(w2, jnp.float32)[plan.tile_eid]       # (nt, f, d)
    xf = jnp.asarray(xs3, jnp.float32)
    h = jnp.einsum("tbd,tdf->tbf", xf, w1g)
    h = jax.nn.silu(h) * jnp.einsum("tbd,tdf->tbf", xf, w3g)
    ys = jnp.einsum("tbf,tfd->tbd", h, w2g)
    ys = constrain(ys, "model", None, None, tag="ep_sorted")
    return ys.reshape(P, d).astype(xs.dtype)


def combine_scatter(ys: jnp.ndarray, plan: DispatchPlan,
                    num_tokens: int, out_dtype) -> jnp.ndarray:
    """Scatter-combine per-row expert outputs back to token order:
    y[t] = sum over t's kept pairs of gate_w * FFN_e(x[t])."""
    P = plan.padded_rows
    rows = ys[jnp.minimum(plan.dest, P - 1)]          # (N, d)
    contrib = plan.s_w[:, None] * jnp.asarray(rows, jnp.float32)
    y = jnp.zeros((num_tokens, ys.shape[1]), jnp.float32)
    y = y.at[plan.s_tok].add(contrib)
    return y.astype(out_dtype)


def group_token_loads(counts: jnp.ndarray, num_groups: int) -> jnp.ndarray:
    """Real per-device-group load: token-assignment rows landing on each
    contiguous expert group (the EP shard map), from actual segment
    sizes — what a device computes under sorted dispatch, as opposed to
    the E/G * C rows the capacity-padded einsum path always pays.

    Non-divisible E: groups are ceil(E/G) experts wide with the last
    group(s) smaller (zero-padded), matching ``ep_select`` and
    ``contiguous_placement`` — the old code silently collapsed to one
    group, reporting the whole batch as one shard's load."""
    E = counts.shape[0]
    per = -(-E // num_groups)
    padded = jnp.pad(counts, (0, num_groups * per - E))
    return padded.reshape(num_groups, per).sum(-1)


def sorted_expert_ffn(x: jnp.ndarray, w1: jnp.ndarray, w3: jnp.ndarray,
                      w2: jnp.ndarray, idx: jnp.ndarray, w: jnp.ndarray, *,
                      block_t: Optional[int] = None,
                      capacity: Optional[int] = None,
                      max_active: Optional[int] = None,
                      use_kernel: Optional[bool] = None,
                      block_f: int = 512) -> jnp.ndarray:
    """Full sorted pipeline: plan -> gather -> grouped GEMM -> scatter.

    use_kernel: None = auto (Pallas grouped_ffn when it would compile,
    i.e. on TPU; jnp tile-gather einsum elsewhere), True/False forces.
    """
    from repro.kernels.moe_ffn import grouped_ffn_apply
    T = x.shape[0]
    E = w1.shape[0]
    plan = dispatch_plan(idx, w, E, block_t=block_t, capacity=capacity,
                         max_active=max_active)
    xs = gather_tokens(x, plan)
    ys = grouped_ffn_apply(xs, w1, w3, w2, plan, use_kernel=use_kernel,
                           block_f=block_f)
    return combine_scatter(ys, plan, T, x.dtype)
