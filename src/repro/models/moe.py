"""Mixture-of-Experts FFN layer with XShare batch-aware selection as a
first-class routing policy.

Expert compute uses GShard-style capacity-based dense dispatch/combine
einsums: with the expert axis sharded over the mesh "model" axis this
lowers to all-to-all (token-sharded -> expert-sharded -> token-sharded),
i.e. real expert parallelism. The paper's algorithms plug in between the
router softmax and the dispatch: they shrink the *set* of experts any
token may route to, which on the EP mesh bounds the per-shard load
(Alg 5/6) and in the Pallas serving kernel skips inactive experts'
HBM->VMEM weight streaming entirely (kernels/moe_ffn.py).
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import MoEConfig, XSharePolicy
from repro.core import metrics as M
from repro.core import selection
from repro.core.routing import topk_route
from repro.models.layers import dense_init, mlp_apply
from repro.sharding import constrain

OFF = XSharePolicy(mode="off")


def init_moe(key, moe: MoEConfig, d_model: int, dtype,
             stack: Optional[int] = None) -> Dict:
    pre = () if stack is None else (stack,)
    ks = jax.random.split(key, 7)
    E, f = moe.num_experts, moe.d_ff_expert
    p = {
        "wg": dense_init(ks[0], pre + (d_model, E), jnp.float32),
        "w1": dense_init(ks[1], pre + (E, d_model, f), dtype),
        "w3": dense_init(ks[2], pre + (E, d_model, f), dtype),
        "w2": dense_init(ks[3], pre + (E, f, d_model), dtype),
    }
    if moe.num_shared_experts:
        fs = moe.d_ff_shared * moe.num_shared_experts
        p["ws1"] = dense_init(ks[4], pre + (d_model, fs), dtype)
        p["ws3"] = dense_init(ks[5], pre + (d_model, fs), dtype)
        p["ws2"] = dense_init(ks[6], pre + (fs, d_model), dtype)
    return p


def route(p: Dict, x: jnp.ndarray, moe: MoEConfig, policy: XSharePolicy,
          spec_shape: Optional[Tuple[int, int]] = None,
          token_mask: Optional[jnp.ndarray] = None):
    """Router + XShare selection. x: (T, d).

    token_mask: optional (T,) bool — masked-out tokens (inactive
    continuous-batching slots) are dropped from routing entirely: their
    gate mass is zeroed before XShare batch aggregation, their expert
    index becomes -1 (a zero one-hot), so they consume no dispatch
    capacity and never count as activating an expert.

    Returns (idx (T,k), weights (T,k), aux dict of selection metrics).
    """
    logits = jnp.asarray(x, jnp.float32) @ jnp.asarray(p["wg"], jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    if token_mask is not None:
        probs = probs * token_mask[:, None].astype(probs.dtype)
    if policy.mode == "off":
        idx, w = topk_route(logits, moe.top_k, normalize=moe.normalize_gates)
        mask = jnp.ones((moe.num_experts,), bool)
    else:
        idx, w, mask = selection.apply_policy(
            probs, policy, top_k=moe.top_k, spec_shape=spec_shape,
            logits=logits)
    if token_mask is not None:
        idx = jnp.where(token_mask[:, None], idx, -1)
        w = jnp.where(token_mask[:, None], w, 0.0)
    one_hot = jax.nn.one_hot(idx, moe.num_experts, dtype=w.dtype)
    combine = (one_hot * w[..., None]).sum(axis=-2)       # (T, E)
    active = (combine > 0).any(axis=0)
    G = policy.num_groups if moe.num_experts % policy.num_groups == 0 else 1
    # Switch-Transformer load-balance auxiliary: E * sum_e f_e * P_e
    # (f_e = fraction of tokens routed to e, P_e = mean router prob).
    # Real MoEs train with this — without it the router collapses and
    # the batch-activation statistics the paper studies never appear.
    # masked rows are zeroed above, so sums only see live tokens — but
    # the mean must divide by the live-token count, not T, or lb_loss
    # deflates as the running batch empties
    denom = probs.shape[0] if token_mask is None else \
        jnp.maximum(token_mask.sum(), 1).astype(jnp.float32)
    frac = (one_hot.sum(-2) > 0).astype(jnp.float32).sum(0) / denom  # (E,)
    lb = moe.num_experts * (frac * (probs.sum(0) / denom)).sum() / moe.top_k
    aux = {
        "activated_experts": active.sum(),
        "selected_set": mask.sum(),
        "max_group_load": M.max_group_load(active, G),
        "gate_mass": M.gate_mass_captured(probs, mask),
        "lb_loss": lb,
    }
    return idx, w, aux


def expert_ffn(p: Dict, x: jnp.ndarray, idx: jnp.ndarray, w: jnp.ndarray,
               moe: MoEConfig, *, capacity_factor: float = 1.25,
               min_capacity: int = 4,
               capacity: Optional[int] = None,
               group_size: int = 2048) -> jnp.ndarray:
    """GShard capacity-based dispatch -> per-expert FFN -> weighted combine.

    x: (T, d); idx/w: (T, k). Tokens are processed in G groups of
    t <= group_size (G the largest divisor of T meeting that), each group
    getting capacity C = max(min_capacity, ceil(t*k/E * capacity_factor)):
    the (G, t, E, C) dispatch one-hots stay bounded at production token
    counts, and with groups sharded over the data axes and experts over
    "model" the dispatch/combine einsums lower to all-to-all (expert
    parallelism). Tokens beyond an expert's per-group capacity are
    dropped (standard GShard semantics); pass capacity=t for exact,
    drop-free computation (accuracy benchmarks; requires G == 1 to be
    truly global).

    Decode-sized token counts (T <= 32) with a drop-free capacity take a
    dense fast path instead: every expert runs on every token and the
    combine weights zero the unselected ones. At these sizes the
    dispatch one-hots/cumsums/scatter einsums cost far more than the
    (tiny) extra FLOPs — the serving hot loop is per-op-overhead bound,
    not math bound — and the result is the same expert outputs under the
    same gates, with no cross-token capacity coupling at all.
    """
    T, d = x.shape
    E, k = moe.num_experts, idx.shape[-1]
    G = 1
    if T > group_size:
        for cand in range(T // group_size, 0, -1):
            if T % cand == 0 and T // cand <= group_size:
                G = cand
                break
    t = T // G
    if capacity is None:
        C = max(min_capacity, int(-(-t * k * capacity_factor // E)))
        C = min(C, t)
    else:
        C = min(capacity, t)

    # decode-size dense fast path — only off-mesh: it has none of the
    # dispatch path's sharding constraints, so under an EP mesh it would
    # all-gather every expert's weights onto each device
    from repro.sharding import current_mesh
    if G == 1 and C >= T and T <= 32 and current_mesh() is None:
        E_, f = E, p["w1"].shape[-1]
        one_hot = jax.nn.one_hot(idx, E_, dtype=jnp.float32)
        gate = (one_hot * w[..., None].astype(jnp.float32)).sum(-2)  # (T,E)
        # flat GEMMs (XLA CPU/TPU handle one (T, E*f) dot far better
        # than E tiny batched matmuls); gate folds in before w2 — same
        # sum, one fewer (T,E,d) intermediate
        w1f = p["w1"].transpose(1, 0, 2).reshape(d, E_ * f)
        w3f = p["w3"].transpose(1, 0, 2).reshape(d, E_ * f)
        h = (x @ w1f).reshape(T, E_, f)
        h = jax.nn.silu(h) * (x @ w3f).reshape(T, E_, f)
        hg = (h * gate[:, :, None].astype(h.dtype)).reshape(T, E_ * f)
        return (hg @ p["w2"].reshape(E_ * f, d)).astype(x.dtype)

    xg = x.reshape(G, t, d)
    one_hot = jax.nn.one_hot(idx.reshape(G, t, k), E, dtype=jnp.float32)
    gate = (one_hot * w.reshape(G, t, k)[..., None].astype(jnp.float32)
            ).sum(-2)                                      # (G,t,E)
    routed = one_hot.sum(-2)                               # (G,t,E) 0/1
    # position of token within its expert's per-group buffer
    pos = jnp.cumsum(routed, axis=1) - routed              # (G,t,E)
    keep = routed * (pos < C)
    disp = keep[..., None] * jax.nn.one_hot(pos, C, dtype=jnp.float32)
    disp = constrain(disp, "batch", None, "model", None)   # (G,t,E,C)
    xe = jnp.einsum("gtec,gtd->gecd", disp, jnp.asarray(xg, jnp.float32))
    xe = constrain(xe.astype(x.dtype), "batch", "model", None, None)
    h = jnp.einsum("gecd,edf->gecf", xe, p["w1"])
    h = jax.nn.silu(h) * jnp.einsum("gecd,edf->gecf", xe, p["w3"])
    ye = jnp.einsum("gecf,efd->gecd", h, p["w2"])          # (G,E,C,d)
    ye = constrain(ye, "batch", "model", None, None)
    comb = disp * gate[..., None]                          # (G,t,E,C)
    y = jnp.einsum("gtec,gecd->gtd", comb, jnp.asarray(ye, jnp.float32))
    y = constrain(y, "batch", None, None)
    return y.reshape(T, d).astype(x.dtype)


def moe_apply(p: Dict, x: jnp.ndarray, moe: MoEConfig,
              policy: XSharePolicy = OFF, *,
              spec_shape: Optional[Tuple[int, int]] = None,
              capacity_factor: float = 1.25,
              capacity: Optional[int] = None,
              token_mask: Optional[jnp.ndarray] = None):
    """Full MoE layer. x: (..., d) (leading dims flattened internally).

    token_mask: optional bool array matching x's leading dims — tokens
    masked False are excluded from routing (see route()).

    Returns (y, aux). Shared experts (DeepSeek-style) are added
    unconditionally — they are outside the selection problem (Sec 2.1).
    """
    shape = x.shape
    xt = x.reshape(-1, shape[-1])
    tm = None if token_mask is None else token_mask.reshape(-1)
    idx, w, aux = route(p, xt, moe, policy, spec_shape, token_mask=tm)
    y = expert_ffn(p, xt, idx, w, moe, capacity_factor=capacity_factor,
                   capacity=capacity)
    if "ws1" in p:
        y = y + mlp_apply({"w1": p["ws1"], "w3": p["ws3"], "w2": p["ws2"]},
                          xt, "swiglu")
    return y.reshape(shape), aux
