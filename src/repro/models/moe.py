"""Mixture-of-Experts FFN layer with XShare batch-aware selection as a
first-class routing policy.

Expert compute routes through a ``dispatch`` switch (see expert_ffn):

  sorted — the default hot path: argsort token-expert pairs by expert,
           grouped GEMM over occupied expert segments, scatter-combine
           (models/dispatch.py + kernels/moe_ffn.py grouped_ffn).
           Capacity-free; compute and weight traffic scale with the
           experts XShare actually selected, not with E.
  einsum — the GShard capacity-based dense dispatch/combine einsums,
           retained as the reference semantics: with the expert axis
           sharded over the mesh "model" axis the (G, t, E, C) one-hot
           einsums lower to all-to-all.
  dense  — decode-sized fast path: every expert runs on every token and
           the combine weights zero the unselected (per-op-overhead
           bound regime, T <= 32).

The paper's algorithms plug in between the router softmax and the
dispatch: they shrink the *set* of experts any token may route to,
which on the EP mesh bounds the per-shard load (Alg 5/6) and in the
Pallas serving kernels skips inactive experts' HBM->VMEM weight
streaming entirely (kernels/moe_ffn.py).
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import MoEConfig, XSharePolicy
from repro.core import metrics as M
from repro.core import selection
from repro.core.routing import topk_route
from repro.models import dispatch as DSP
from repro.models.layers import dense_init, mlp_apply
from repro.sharding import constrain, current_mesh

OFF = XSharePolicy(mode="off")

DISPATCH_MODES = ("auto", "sorted", "einsum", "dense", "ep")


def policy_max_active(policy: XSharePolicy, num_tokens: int,
                      num_experts: int, *,
                      spec_shape: Optional[Tuple[int, int]] = None) -> int:
    """Static upper bound on |selected expert set| under a policy — the
    XShare budget the sorted path's padded buffer / tile count (and on
    TPU its weight HBM traffic) scales with."""
    E, T = num_experts, num_tokens
    if policy.mode == "batch":
        return min(E, policy.k0 * T + policy.m_l)
    if policy.mode == "ep":
        bound = policy.num_groups * policy.m_g
        if not policy.strict_cap:
            bound += policy.k0 * T
        return min(E, bound)
    if policy.mode == "spec" and spec_shape is not None:
        b, t = spec_shape
        return min(E, b * (policy.k0 * t + policy.m_r) + policy.m_l)
    return E


def init_moe(key, moe: MoEConfig, d_model: int, dtype,
             stack: Optional[int] = None) -> Dict:
    pre = () if stack is None else (stack,)
    ks = jax.random.split(key, 7)
    E, f = moe.num_experts, moe.d_ff_expert
    p = {
        "wg": dense_init(ks[0], pre + (d_model, E), jnp.float32),
        "w1": dense_init(ks[1], pre + (E, d_model, f), dtype),
        "w3": dense_init(ks[2], pre + (E, d_model, f), dtype),
        "w2": dense_init(ks[3], pre + (E, f, d_model), dtype),
    }
    if moe.num_shared_experts:
        fs = moe.d_ff_shared * moe.num_shared_experts
        p["ws1"] = dense_init(ks[4], pre + (d_model, fs), dtype)
        p["ws3"] = dense_init(ks[5], pre + (d_model, fs), dtype)
        p["ws2"] = dense_init(ks[6], pre + (fs, d_model), dtype)
    return p


def route(p: Dict, x: jnp.ndarray, moe: MoEConfig, policy: XSharePolicy,
          spec_shape: Optional[Tuple[int, int]] = None,
          token_mask: Optional[jnp.ndarray] = None,
          spec_priors: Optional[jnp.ndarray] = None):
    """Router + XShare selection. x: (T, d).

    token_mask: optional (T,) bool — masked-out tokens (inactive
    continuous-batching slots) are dropped from routing entirely: their
    gate mass is zeroed before XShare batch aggregation, their expert
    index becomes -1 (a zero one-hot), so they consume no dispatch
    capacity and never count as activating an expert.

    spec_priors: optional (b, E) per-request gate-histogram priors for
    mode="spec" correlation-aware selection (b = spec_shape[0]).

    Returns (idx (T,k), weights (T,k), combine (T,E) f32, aux dict).
    The combine matrix (gate weight per token-expert cell) is built
    exactly once here and reused by every downstream consumer — the
    dense dispatch path, the Pallas masked-FFN kernel, and the aux
    metrics — instead of each rebuilding the (T, k, E) one-hot.
    """
    logits = jnp.asarray(x, jnp.float32) @ jnp.asarray(p["wg"], jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    if token_mask is not None:
        probs = probs * token_mask[:, None].astype(probs.dtype)
    if policy.mode == "off":
        idx, w = topk_route(logits, moe.top_k, normalize=moe.normalize_gates)
        mask = jnp.ones((moe.num_experts,), bool)
    else:
        idx, w, mask = selection.apply_policy(
            probs, policy, top_k=moe.top_k, spec_shape=spec_shape,
            logits=logits, priors=spec_priors)
    if token_mask is not None:
        idx = jnp.where(token_mask[:, None], idx, -1)
        w = jnp.where(token_mask[:, None], w, 0.0)
    one_hot = jax.nn.one_hot(idx, moe.num_experts, dtype=w.dtype)
    combine = (one_hot * w[..., None]).sum(axis=-2)       # (T, E)
    active = (combine > 0).any(axis=0)
    # group math handles E % G != 0 (ceil-width groups, last smaller),
    # so no divisibility fallback: aux loads always reflect G shards
    G = policy.num_groups
    # Switch-Transformer load-balance auxiliary: E * sum_e f_e * P_e
    # (f_e = fraction of tokens routed to e, P_e = mean router prob).
    # Real MoEs train with this — without it the router collapses and
    # the batch-activation statistics the paper studies never appear.
    # masked rows are zeroed above, so sums only see live tokens — but
    # the mean must divide by the live-token count, not T, or lb_loss
    # deflates as the running batch empties
    denom = probs.shape[0] if token_mask is None else \
        jnp.maximum(token_mask.sum(), 1).astype(jnp.float32)
    frac = (one_hot.sum(-2) > 0).astype(jnp.float32).sum(0) / denom  # (E,)
    lb = moe.num_experts * (frac * (probs.sum(0) / denom)).sum() / moe.top_k
    # real per-expert segment sizes (what each EP shard computes under
    # sorted dispatch) — not the E/G * C rows capacity padding implies
    counts = jnp.zeros((moe.num_experts,), jnp.int32).at[idx].add(
        (w != 0.0).astype(jnp.int32), mode="drop")
    aux = {
        "activated_experts": active.sum(),
        "selected_set": mask.sum(),
        "max_group_load": M.max_group_load(active, G),
        "max_group_tokens": DSP.group_token_loads(counts, G).max(),
        "gate_mass": M.gate_mass_captured(probs, mask),
        "lb_loss": lb,
    }
    if spec_shape is not None:
        # per-request gate histogram over this pass's live tokens — the
        # raw material for the scheduler's correlation priors (fed back
        # as spec_priors on later rounds). masked rows were zeroed above,
        # so the mean divides by each request's live-token count.
        b, t = spec_shape
        pr = probs.reshape(b, t, probs.shape[-1])
        if token_mask is not None:
            denom_r = jnp.maximum(
                token_mask.reshape(b, t).sum(-1, keepdims=True), 1)
        else:
            denom_r = jnp.full((b, 1), t)
        aux["req_gate_hist"] = pr.sum(axis=1) / denom_r      # (b, E)
    return idx, w, combine, aux


def einsum_capacity(tokens_per_group: int, top_k: int, num_experts: int,
                    capacity_factor: float, *, min_capacity: int = 4,
                    capacity: Optional[int] = None) -> int:
    """Per-expert per-group buffer size C of the einsum dispatch path —
    the one place the GShard capacity rule lives (benchmarks derive
    their byte models from here, not from a copy)."""
    t = tokens_per_group
    if capacity is not None:
        return min(capacity, t)
    c = max(min_capacity,
            int(-(-t * top_k * capacity_factor // num_experts)))
    return min(c, t)


def expert_ffn(p: Dict, x: jnp.ndarray, idx: jnp.ndarray, w: jnp.ndarray,
               moe: MoEConfig, *, capacity_factor: float = 1.25,
               min_capacity: int = 4,
               capacity: Optional[int] = None,
               group_size: int = 2048,
               dispatch: str = "auto",
               combine: Optional[jnp.ndarray] = None,
               max_active: Optional[int] = None) -> jnp.ndarray:
    """Routed-expert compute behind the dispatch switch.

    x: (T, d); idx/w: (T, k); combine: optional (T, E) gate matrix from
    route() (reused by the dense path instead of rebuilding the one-hot).

    dispatch:
      "sorted" — argsort pairs by expert, grouped GEMM over occupied
                 segments (Pallas grouped_ffn on TPU, tile-gather einsum
                 elsewhere), scatter-combine. Capacity-free unless
                 ``capacity`` is given (then per-expert clamp, first
                 tokens kept — the EP load bound). max_active bounds the
                 padded layout by the XShare budget.
      "einsum" — GShard (G, t, E, C) one-hot dispatch/combine einsums,
                 tokens in G groups of t <= group_size, per-group
                 capacity C = max(min_capacity, ceil(t*k/E * cf)).
                 Tokens beyond capacity are dropped; capacity=t is
                 drop-free (requires G == 1 to be truly global). The
                 reference semantics; on an EP mesh the einsums lower
                 to all-to-all.
      "dense"  — every expert on every token, combine weights zero the
                 unselected. Cheapest at decode sizes where per-op
                 overhead dominates; only off-mesh (it would all-gather
                 every expert's weights onto each device).
      "ep"     — real expert-parallel execution through the EPExecutor
                 bound via ``repro.ep.ep_context``: per-shard sort,
                 ragged all-to-all row exchange, local grouped GEMM on
                 placement-assigned experts, reverse exchange + combine
                 (ep/executor.py). Numerically exact vs "sorted"; with
                 no executor bound it degrades to "sorted" (the
                 bit-identical single-device path).
      "auto"   — dense for decode-sized drop-free batches off-mesh,
                 sorted otherwise.
    """
    T, d = x.shape
    E, k = moe.num_experts, idx.shape[-1]
    assert dispatch in DISPATCH_MODES, dispatch
    if dispatch == "ep":
        from repro import ep as EP
        ex = EP.current_executor()
        if ex is not None:
            return ex.ffn(x, p["w1"], p["w3"], p["w2"], idx, w
                          ).astype(x.dtype)
        dispatch = "sorted"                   # graceful single-device path
    G = 1
    if T > group_size:
        for cand in range(T // group_size, 0, -1):
            if T % cand == 0 and T // cand <= group_size:
                G = cand
                break
    t = T // G
    C = einsum_capacity(t, k, E, capacity_factor,
                        min_capacity=min_capacity, capacity=capacity)

    if dispatch == "auto":
        if G == 1 and C >= T and T <= 32 and current_mesh() is None:
            dispatch = "dense"
        else:
            dispatch = "sorted"

    if dispatch == "dense":
        E_, f = E, p["w1"].shape[-1]
        if combine is None:
            one_hot = jax.nn.one_hot(idx, E_, dtype=jnp.float32)
            combine = (one_hot * w[..., None].astype(jnp.float32)).sum(-2)
        gate = combine                                    # (T, E)
        # flat GEMMs (XLA CPU/TPU handle one (T, E*f) dot far better
        # than E tiny batched matmuls); gate folds in before w2 — same
        # sum, one fewer (T,E,d) intermediate
        w1f = p["w1"].transpose(1, 0, 2).reshape(d, E_ * f)
        w3f = p["w3"].transpose(1, 0, 2).reshape(d, E_ * f)
        h = (x @ w1f).reshape(T, E_, f)
        h = jax.nn.silu(h) * (x @ w3f).reshape(T, E_, f)
        hg = (h * gate[:, :, None].astype(h.dtype)).reshape(T, E_ * f)
        return (hg @ p["w2"].reshape(E_ * f, d)).astype(x.dtype)

    if dispatch == "sorted":
        return DSP.sorted_expert_ffn(
            x, p["w1"], p["w3"], p["w2"], idx, w,
            capacity=capacity, max_active=max_active)

    xg = x.reshape(G, t, d)
    one_hot = jax.nn.one_hot(idx.reshape(G, t, k), E, dtype=jnp.float32)
    gate = (one_hot * w.reshape(G, t, k)[..., None].astype(jnp.float32)
            ).sum(-2)                                      # (G,t,E)
    routed = one_hot.sum(-2)                               # (G,t,E) 0/1
    # position of token within its expert's per-group buffer
    pos = jnp.cumsum(routed, axis=1) - routed              # (G,t,E)
    keep = routed * (pos < C)
    disp = keep[..., None] * jax.nn.one_hot(pos, C, dtype=jnp.float32)
    disp = constrain(disp, "batch", None, "model", None)   # (G,t,E,C)
    xe = jnp.einsum("gtec,gtd->gecd", disp, jnp.asarray(xg, jnp.float32))
    xe = constrain(xe.astype(x.dtype), "batch", "model", None, None)
    h = jnp.einsum("gecd,edf->gecf", xe, p["w1"])
    h = jax.nn.silu(h) * jnp.einsum("gecd,edf->gecf", xe, p["w3"])
    ye = jnp.einsum("gecf,efd->gecd", h, p["w2"])          # (G,E,C,d)
    ye = constrain(ye, "batch", "model", None, None)
    comb = disp * gate[..., None]                          # (G,t,E,C)
    y = jnp.einsum("gtec,gecd->gtd", comb, jnp.asarray(ye, jnp.float32))
    y = constrain(y, "batch", None, None)
    return y.reshape(T, d).astype(x.dtype)


def moe_apply(p: Dict, x: jnp.ndarray, moe: MoEConfig,
              policy: XSharePolicy = OFF, *,
              spec_shape: Optional[Tuple[int, int]] = None,
              capacity_factor: float = 1.25,
              capacity: Optional[int] = None,
              token_mask: Optional[jnp.ndarray] = None,
              dispatch: str = "auto",
              spec_priors: Optional[jnp.ndarray] = None):
    """Full MoE layer. x: (..., d) (leading dims flattened internally).

    token_mask: optional bool array matching x's leading dims — tokens
    masked False are excluded from routing (see route()).

    dispatch: expert-compute path, see expert_ffn. The XShare budget
    bound (policy_max_active) sizes the sorted path's padded layout.

    spec_priors: optional (b, E) correlation priors for mode="spec"
    (see route()).

    Returns (y, aux). Shared experts (DeepSeek-style) are added
    unconditionally — they are outside the selection problem (Sec 2.1).
    """
    shape = x.shape
    xt = x.reshape(-1, shape[-1])
    tm = None if token_mask is None else token_mask.reshape(-1)
    idx, w, combine, aux = route(p, xt, moe, policy, spec_shape,
                                 token_mask=tm, spec_priors=spec_priors)
    ma = policy_max_active(policy, xt.shape[0], moe.num_experts,
                           spec_shape=spec_shape)
    y = expert_ffn(p, xt, idx, w, moe, capacity_factor=capacity_factor,
                   capacity=capacity, dispatch=dispatch, combine=combine,
                   max_active=ma)
    if "ws1" in p:
        y = y + mlp_apply({"w1": p["ws1"], "w3": p["ws3"], "w2": p["ws2"]},
                          xt, "swiglu")
    return y.reshape(shape), aux
