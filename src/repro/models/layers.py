"""Basic neural blocks: norms, MLPs, embeddings. Pure functional, params
are plain dict pytrees; stacked-layer leaves carry a leading (L, ...) axis
consumed by lax.scan in model.py."""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp


def rms_norm(x: jnp.ndarray, weight: jnp.ndarray, eps: float) -> jnp.ndarray:
    """RMSNorm in f32, cast back to input dtype."""
    dtype = x.dtype
    xf = jnp.asarray(x, jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps) * jnp.asarray(weight, jnp.float32)
    return out.astype(dtype)


def dense_init(key, shape, dtype, scale: float = 0.02) -> jnp.ndarray:
    return (scale * jax.random.normal(key, shape, jnp.float32)).astype(dtype)


def mlp_init(key, d_model: int, d_ff: int, dtype, act: str,
             stack: int | None = None) -> Dict:
    """SwiGLU (w1,w3,w2) or GELU (w1,w2) MLP params; optionally stacked."""
    pre = () if stack is None else (stack,)
    k1, k2, k3 = jax.random.split(key, 3)
    p = {
        "w1": dense_init(k1, pre + (d_model, d_ff), dtype),
        "w2": dense_init(k2, pre + (d_ff, d_model), dtype),
    }
    if act == "swiglu":
        p["w3"] = dense_init(k3, pre + (d_model, d_ff), dtype)
    return p


def mlp_apply(params: Dict, x: jnp.ndarray, act: str) -> jnp.ndarray:
    """x: (..., d). Megatron-style: hidden dim is the sharded axis."""
    h = x @ params["w1"]
    if act == "swiglu":
        h = jax.nn.silu(h) * (x @ params["w3"])
    elif act == "gelu":
        h = jax.nn.gelu(h)
    else:
        raise ValueError(act)
    return h @ params["w2"]


def embed_lookup(table: jnp.ndarray, tokens: jnp.ndarray) -> jnp.ndarray:
    """table: (V, d); tokens int32 (...,) -> (..., d)."""
    return jnp.take(table, tokens, axis=0)


def cross_entropy(logits: jnp.ndarray, labels: jnp.ndarray,
                  mask: jnp.ndarray | None = None) -> jnp.ndarray:
    """Mean next-token CE. logits (..., V) f-any, labels int32 (...,)."""
    logits = jnp.asarray(logits, jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = logz - gold
    if mask is not None:
        mask = jnp.asarray(mask, jnp.float32)
        return (nll * mask).sum() / jnp.maximum(mask.sum(), 1.0)
    return nll.mean()
