"""Model composition: embed -> scanned decoder blocks -> head, for all six
assigned architecture families (dense / moe / ssm / hybrid / vlm / audio).

Layers are stacked on a leading (L, ...) axis and consumed with lax.scan
(compile time stays flat in depth — required for the 94-layer MoE).
The hybrid (Zamba2) family interleaves a *weight-shared* attention block
every `attn_every` SSM layers via a Python loop over groups, each group
scanning its slice of the stacked SSM params.

Three entry points mirror the assigned input shapes:
  forward      — full-sequence, no cache (train_4k)
  prefill      — full-sequence, builds the decode cache (prefill_32k)
  decode_step  — T new tokens (T=1 decode, T=1+L_s speculative verify)
                 against the cache (decode_32k / long_500k)
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, XSharePolicy
from repro.models import attention as A
from repro.models import ssm as S
from repro.models.layers import (cross_entropy, dense_init, mlp_apply,
                                 mlp_init, rms_norm)
from repro.models.moe import OFF, init_moe, moe_apply
from repro.sharding import constrain

WINDOW_MARGIN = 512  # rolling-cache slack: spec-verify never overwrites
                     # in-window slots (needs >= spec_len; see
                     # attention.py), and window+margin stays divisible
                     # by every mesh-axis extent (16/256/512) so the
                     # cache sequence dim shards cleanly.


# ----------------------------------------------------------------- init ---

def init_params(cfg: ArchConfig, key, dtype=jnp.float32) -> Dict:
    ks = jax.random.split(key, 10)
    L, d, V = cfg.num_layers, cfg.d_model, cfg.padded_vocab
    params: Dict = {}
    if cfg.family == "audio":
        params["embed"] = dense_init(ks[0], (cfg.num_codebooks, V, d), dtype)
    else:
        params["embed"] = dense_init(ks[0], (V, d), dtype)

    layers: Dict = {}
    if cfg.family in ("dense", "vlm", "audio"):
        layers["attn_norm"] = jnp.ones((L, d), dtype)
        layers["attn"] = A.init_attn(ks[1], cfg.attn, d, dtype, stack=L)
        layers["mlp_norm"] = jnp.ones((L, d), dtype)
        layers["mlp"] = mlp_init(ks[2], d, cfg.d_ff, dtype, cfg.act, stack=L)
    elif cfg.family == "moe":
        layers["attn_norm"] = jnp.ones((L, d), dtype)
        layers["attn"] = A.init_attn(ks[1], cfg.attn, d, dtype, stack=L)
        layers["moe_norm"] = jnp.ones((L, d), dtype)
        layers["moe"] = init_moe(ks[2], cfg.moe, d, dtype, stack=L)
    elif cfg.family in ("ssm", "hybrid"):
        layers["norm"] = jnp.ones((L, d), dtype)
        layers["ssm"] = S.init_ssm(ks[1], cfg.ssm, d, dtype, stack=L)
    else:
        raise ValueError(cfg.family)
    params["layers"] = layers

    if cfg.family == "hybrid":
        params["shared_attn"] = {
            "attn_norm": jnp.ones((d,), dtype),
            "attn": A.init_attn(ks[3], cfg.attn, d, dtype),
            "mlp_norm": jnp.ones((d,), dtype),
            "mlp": mlp_init(ks[4], d, cfg.d_ff, dtype, cfg.act),
        }
    params["final_norm"] = jnp.ones((d,), dtype)
    if not cfg.tie_embeddings:
        if cfg.family == "audio":
            params["lm_head"] = dense_init(ks[5], (cfg.num_codebooks, d, V),
                                           dtype)
        else:
            params["lm_head"] = dense_init(ks[5], (d, V), dtype)
    return params


def param_count(params) -> int:
    return sum(p.size for p in jax.tree_util.tree_leaves(params))


# ---------------------------------------------------------- embed / head --

def embed_tokens(cfg: ArchConfig, params, tokens: jnp.ndarray) -> jnp.ndarray:
    if cfg.family == "audio":
        # tokens (B, S, K): sum of per-codebook embeddings
        parts = [jnp.take(params["embed"][k], tokens[..., k], axis=0)
                 for k in range(cfg.num_codebooks)]
        return sum(parts)
    return jnp.take(params["embed"], tokens, axis=0)


def lm_head_apply(cfg: ArchConfig, params, x: jnp.ndarray) -> jnp.ndarray:
    if cfg.family == "audio":
        if cfg.tie_embeddings:
            logits = jnp.einsum("bsd,kvd->bskv", x, params["embed"])
        else:
            logits = jnp.einsum("bsd,kdv->bskv", x, params["lm_head"])
    else:
        table = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
        logits = x @ table
    if cfg.padded_vocab != cfg.vocab_size:
        pad = jnp.arange(cfg.padded_vocab) >= cfg.vocab_size
        logits = jnp.where(pad, -1e30, logits)
    return logits


# ------------------------------------------------------------ block fns ---

def _attn_block_full(cfg: ArchConfig, lp: Dict, x: jnp.ndarray,
                     positions: jnp.ndarray,
                     window: Optional[int]) -> jnp.ndarray:
    """Pre-norm attention sub-block, full sequence. Returns residual-added x."""
    B, T = x.shape[:2]
    h = rms_norm(x, lp["attn_norm"], cfg.norm_eps)
    q, k, v = A.qkv_project(lp["attn"], h, positions, cfg.attn, cfg.norm_eps)
    a = A.flash_attention(q, k, v, causal=True, window=window)
    return x + a.reshape(B, T, -1) @ lp["attn"]["wo"]


def _attn_block_decode(cfg: ArchConfig, lp: Dict, x: jnp.ndarray,
                       positions: jnp.ndarray, ck, cv, cur_len,
                       window: Optional[int]):
    B, T = x.shape[:2]
    h = rms_norm(x, lp["attn_norm"], cfg.norm_eps)
    q, k, v = A.qkv_project(lp["attn"], h, positions, cfg.attn, cfg.norm_eps)
    ck = A.update_cache(ck, k, cur_len, window=window)
    cv = A.update_cache(cv, v, cur_len, window=window)
    a = A.cached_attention(q, ck, cv, cur_len, window=window)
    return x + a.reshape(B, T, -1) @ lp["attn"]["wo"], ck, cv


def _ffn_block(cfg: ArchConfig, lp: Dict, x: jnp.ndarray,
               policy: XSharePolicy, spec_shape, capacity,
               capacity_factor: float,
               token_mask: Optional[jnp.ndarray] = None,
               dispatch: str = "auto",
               spec_priors: Optional[jnp.ndarray] = None):
    if cfg.family == "moe":
        h = rms_norm(x, lp["moe_norm"], cfg.norm_eps)
        y, aux = moe_apply(lp["moe"], h, cfg.moe, policy,
                           spec_shape=spec_shape, capacity=capacity,
                           capacity_factor=capacity_factor,
                           token_mask=token_mask, dispatch=dispatch,
                           spec_priors=spec_priors)
        return x + y, aux
    h = rms_norm(x, lp["mlp_norm"], cfg.norm_eps)
    return x + mlp_apply(lp["mlp"], h, cfg.act), {}


def _shared_attn_block(cfg: ArchConfig, sp: Dict, x: jnp.ndarray,
                       positions: jnp.ndarray, window: Optional[int],
                       cache=None, cur_len=None):
    """Hybrid family's weight-shared attention+MLP block."""
    B, T = x.shape[:2]
    h = rms_norm(x, sp["attn_norm"], cfg.norm_eps)
    q, k, v = A.qkv_project(sp["attn"], h, positions, cfg.attn, cfg.norm_eps)
    new_cache = None
    if cache is None:
        a = A.flash_attention(q, k, v, causal=True, window=window)
    else:
        ck, cv = cache
        ck = A.update_cache(ck, k, cur_len, window=window)
        cv = A.update_cache(cv, v, cur_len, window=window)
        a = A.cached_attention(q, ck, cv, cur_len, window=window)
        new_cache = (ck, cv)
    x = x + a.reshape(B, T, -1) @ sp["attn"]["wo"]
    h = rms_norm(x, sp["mlp_norm"], cfg.norm_eps)
    x = x + mlp_apply(sp["mlp"], h, cfg.act)
    return x, new_cache


def _num_shared_apps(cfg: ArchConfig) -> int:
    return -(-cfg.num_layers // cfg.attn_every) if cfg.attn_every else 0


# -------------------------------------------------------------- forward ---

def _backbone(cfg: ArchConfig, params, tokens: jnp.ndarray, *,
              prefix_embeds: Optional[jnp.ndarray] = None,
              policy: XSharePolicy = OFF,
              spec_shape: Optional[Tuple[int, int]] = None,
              remat: bool = False,
              window: Optional[int] = None,
              capacity: Optional[int] = None,
              capacity_factor: float = 1.25,
              dispatch: str = "auto"):
    """Full-sequence backbone. Returns (final-normed hidden states, aux).

    window overrides cfg.attn.sliding_window (forced-window long-context
    variant); prefix_embeds (B, P, d) are prepended (vlm/audio stubs).
    """
    x = embed_tokens(cfg, params, tokens)
    if prefix_embeds is not None:
        x = jnp.concatenate([prefix_embeds.astype(x.dtype), x], axis=1)
    B, T = x.shape[:2]
    positions = jnp.arange(T)[None, :].repeat(B, axis=0)
    eff_window = window if window is not None else (
        cfg.attn.sliding_window if cfg.attn else None)

    if cfg.family in ("dense", "vlm", "audio", "moe"):
        def layer(h, lp):
            # sequence parallelism: the residual stream (and thus the
            # remat checkpoint stack) lives sharded (batch, seq/model);
            # XLA inserts all-gather before attn / reduce-scatter after
            h = constrain(h, "batch", "model", None, tag="seqpar")
            h = _attn_block_full(cfg, lp, h, positions, eff_window)
            h, aux = _ffn_block(cfg, lp, h, policy, spec_shape, capacity,
                                capacity_factor, dispatch=dispatch)
            return h, aux
        f = jax.checkpoint(layer) if remat else layer
        x, aux = jax.lax.scan(f, x, params["layers"])
    elif cfg.family == "ssm":
        def layer(h, lp):
            h = constrain(h, "batch", "model", None, tag="seqpar")   # sequence parallel
            hn = rms_norm(h, lp["norm"], cfg.norm_eps)
            y, _ = S.ssm_forward(lp["ssm"], hn, cfg.ssm, cfg.d_model,
                                 cfg.norm_eps)
            return h + y, None
        f = jax.checkpoint(layer) if remat else layer
        x, aux = jax.lax.scan(f, x, params["layers"])
    elif cfg.family == "hybrid":
        ae = cfg.attn_every
        def layer(h, lp):
            h = constrain(h, "batch", "model", None, tag="seqpar")   # sequence parallel
            hn = rms_norm(h, lp["norm"], cfg.norm_eps)
            y, _ = S.ssm_forward(lp["ssm"], hn, cfg.ssm, cfg.d_model,
                                 cfg.norm_eps)
            return h + y, None
        f = jax.checkpoint(layer) if remat else layer
        for g in range(_num_shared_apps(cfg)):
            x, _ = _shared_attn_block(cfg, params["shared_attn"], x,
                                      positions, eff_window)
            lo, hi = g * ae, min((g + 1) * ae, cfg.num_layers)
            gp = jax.tree_util.tree_map(lambda a: a[lo:hi], params["layers"])
            x, _ = jax.lax.scan(f, x, gp)
        aux = None
    else:
        raise ValueError(cfg.family)

    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    return x, (aux if isinstance(aux, dict) else {})


def forward(cfg: ArchConfig, params, tokens: jnp.ndarray, **kw):
    """Full-sequence forward. Returns (logits over all positions, aux)."""
    x, aux = _backbone(cfg, params, tokens, **kw)
    return lm_head_apply(cfg, params, x), aux


def _fused_head_ce(cfg: ArchConfig, params, x: jnp.ndarray,
                   targets: jnp.ndarray, chunk: int = 512) -> jnp.ndarray:
    """Head projection + cross-entropy fused over sequence chunks with
    per-chunk remat: the full (B, S, V) f32 logits tensor (gigabytes at
    128k-256k vocab) never materializes, forward or backward."""
    B, Sx = x.shape[0], x.shape[1]
    c = min(chunk, Sx)
    n = -(-Sx // c)
    pad = n * c - Sx
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0)))
        pad_t = ((0, 0), (0, pad)) + ((0, 0),) * (targets.ndim - 2)
        targets = jnp.pad(targets, pad_t)
    valid = (jnp.arange(n * c) < Sx)

    xs = x.reshape(B, n, c, -1).transpose(1, 0, 2, 3)
    ts = targets.reshape((B, n, c) + targets.shape[2:]).transpose(
        (1, 0, 2) + tuple(range(3, targets.ndim + 1)))
    ms = valid.reshape(n, c)

    @jax.checkpoint
    def chunk_fn(carry, inp):
        xc, tc, mc = inp
        logits = lm_head_apply(cfg, params, xc)       # (B,c,V[,K..])
        logits = jnp.asarray(logits, jnp.float32)
        logz = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, tc[..., None], axis=-1)[..., 0]
        nll = logz - gold                             # (B,c[,K])
        if nll.ndim == 3:                             # audio codebooks
            nll = nll.mean(-1)
        mcf = mc[None, :].astype(jnp.float32)
        return (carry[0] + (nll * mcf).sum(), carry[1] + mcf.sum() * B), None

    (tot, cnt), _ = jax.lax.scan(chunk_fn, (jnp.zeros(()), jnp.zeros(())),
                                 (xs, ts, ms))
    return tot / jnp.maximum(cnt, 1.0)


def loss_fn(cfg: ArchConfig, params, tokens: jnp.ndarray, *,
            prefix_embeds: Optional[jnp.ndarray] = None,
            policy: XSharePolicy = OFF, remat: bool = True,
            capacity_factor: float = 1.25,
            lb_weight: float = 0.02):
    """Mean next-token cross-entropy (prefix positions excluded), via the
    fused chunked head+CE, plus the MoE load-balance auxiliary."""
    x, aux = _backbone(cfg, params, tokens, prefix_embeds=prefix_embeds,
                       policy=policy, remat=remat,
                       capacity_factor=capacity_factor)
    P = 0 if prefix_embeds is None else prefix_embeds.shape[1]
    # hidden at position P+i predicts tokens[:, i+1]
    loss = _fused_head_ce(cfg, params, x[:, P:-1], tokens[:, 1:])
    if lb_weight and isinstance(aux, dict) and "lb_loss" in aux:
        loss = loss + lb_weight * jnp.mean(aux["lb_loss"])
    return loss, aux


# ---------------------------------------------------------------- cache ---

def effective_window(cfg: ArchConfig, *, force_window: Optional[int] = None
                     ) -> Optional[int]:
    if force_window is not None:
        return force_window
    return cfg.attn.sliding_window if cfg.attn else None


def init_cache(cfg: ArchConfig, batch: int, cache_len: int, dtype, *,
               force_window: Optional[int] = None) -> Dict:
    """Decode cache pytree. cache_len must include room for new tokens
    (spec verify) when no window is set.

    ``cur_len`` is a per-slot (batch,) vector — the universal cache
    representation: lockstep decode advances every row together,
    speculative decode rolls rows back raggedly, and the continuous-
    batching scheduler (serving/scheduler.py) gives every slot an
    independent lifetime via insert_request / evict_slot below.
    """
    L, d = cfg.num_layers, cfg.d_model
    cache: Dict = {"cur_len": jnp.zeros((batch,), jnp.int32)}
    win = effective_window(cfg, force_window=force_window)
    C = (win + WINDOW_MARGIN) if win is not None else cache_len

    def kv(n_stack):
        a = cfg.attn
        shape = (n_stack, batch, C, a.num_kv_heads, a.head_dim)
        return jnp.zeros(shape, dtype), jnp.zeros(shape, dtype)

    if cfg.family in ("dense", "vlm", "audio", "moe"):
        cache["kv_k"], cache["kv_v"] = kv(L)
    if cfg.family in ("ssm", "hybrid"):
        d_inner, nh, d_bc = S.dims(cfg.ssm, d)
        K = cfg.ssm.d_conv
        cache["conv_x"] = jnp.zeros((L, batch, K - 1, d_inner), dtype)
        cache["conv_B"] = jnp.zeros((L, batch, K - 1, d_bc), dtype)
        cache["conv_C"] = jnp.zeros((L, batch, K - 1, d_bc), dtype)
        cache["state"] = jnp.zeros(
            (L, batch, nh, cfg.ssm.head_dim, cfg.ssm.d_state), jnp.float32)
    if cfg.family == "hybrid":
        cache["shared_k"], cache["shared_v"] = kv(_num_shared_apps(cfg))
    return cache


# Every stacked cache array carries batch on axis 1 (leading axis is the
# layer / shared-block stack); cur_len is the lone per-slot (B,) vector.
_CACHE_BATCH_AXIS = 1


def insert_request(cache: Dict, req_cache: Dict, slot, src=0) -> Dict:
    """Cache surgery: copy row `src` of a prefilled cache (batch >= 1 —
    the scheduler batch-prefills simultaneous admissions) into row `slot`
    of a running batch cache.

    The whole per-slot extent (full cache sequence axis included) is
    overwritten, so whatever a previous occupant — or a compute-masked
    empty slot — left behind is erased. `slot` / `src` may be Python ints
    or traced scalars, so one jitted copy serves every (slot, src) pair.
    """
    out = {}
    for k, v in cache.items():
        if k == "cur_len":
            r = jax.lax.dynamic_slice_in_dim(req_cache[k], src, 1, axis=0)
            out[k] = jax.lax.dynamic_update_slice(v, r.astype(v.dtype),
                                                  (slot,))
        else:
            r = jax.lax.dynamic_slice_in_dim(req_cache[k], src, 1,
                                             axis=_CACHE_BATCH_AXIS)
            start = (0, slot) + (0,) * (v.ndim - _CACHE_BATCH_AXIS - 1)
            out[k] = jax.lax.dynamic_update_slice(v, r.astype(v.dtype),
                                                  start)
    return out


def evict_slot(cache: Dict, slot, *, scrub: bool = False) -> Dict:
    """Cache surgery: mark row `slot` free (cur_len = 0).

    KV / state contents are left in place — they are dead weight until
    insert_request overwrites the row, and the scheduler compute-masks
    evicted slots so they never influence live requests.

    scrub=True (static) additionally zeroes the slot's every cache
    array: numerics quarantine evicts poisoned requests this way so
    non-finite values cannot outlive the request through any path the
    compute mask doesn't cover.
    """
    out = {}
    for k, v in cache.items():
        if k == "cur_len":
            out[k] = jax.lax.dynamic_update_slice(
                v, jnp.zeros((1,), v.dtype), (slot,))
        elif scrub:
            row = jnp.zeros((v.shape[0], 1) + v.shape[2:], v.dtype)
            start = (0, slot) + (0,) * (v.ndim - _CACHE_BATCH_AXIS - 1)
            out[k] = jax.lax.dynamic_update_slice(v, row, start)
        else:
            out[k] = v
    return out


# -------------------------------------------------------------- prefill ---

def _build_cache_slice(k: jnp.ndarray, C: int, win: Optional[int]
                       ) -> jnp.ndarray:
    """Arrange full-sequence kv (B,S,Hkv,dh) into a cache buffer (B,C,...)."""
    B, Ss = k.shape[0], k.shape[1]
    if win is None:
        assert Ss <= C, (Ss, C)
        buf = jnp.zeros((B, C) + k.shape[2:], k.dtype)
        return jax.lax.dynamic_update_slice(buf, k, (0, 0, 0, 0))
    n = min(Ss, C)
    tail = k[:, Ss - n:]
    slots = (jnp.arange(Ss - n, Ss)) % C
    buf = jnp.zeros((B, C) + k.shape[2:], k.dtype)
    return buf.at[:, slots].set(tail)


def prefill(cfg: ArchConfig, params, tokens: jnp.ndarray, *,
            cache_len: int,
            prefix_embeds: Optional[jnp.ndarray] = None,
            policy: XSharePolicy = OFF,
            force_window: Optional[int] = None,
            cache_dtype=None,
            capacity_factor: float = 1.25,
            dispatch: str = "auto"):
    """Process the prompt, build the decode cache. Returns
    (last-position logits (B, V[,K]), cache, aux)."""
    x = embed_tokens(cfg, params, tokens)
    if prefix_embeds is not None:
        x = jnp.concatenate([prefix_embeds.astype(x.dtype), x], axis=1)
    B, T = x.shape[:2]
    positions = jnp.arange(T)[None, :].repeat(B, axis=0)
    win = effective_window(cfg, force_window=force_window)
    C = (win + WINDOW_MARGIN) if win is not None else cache_len
    cdt = cache_dtype or x.dtype

    cache = init_cache(cfg, B, cache_len, cdt, force_window=force_window)
    cache["cur_len"] = jnp.full((B,), T, jnp.int32)

    if cfg.family in ("dense", "vlm", "audio", "moe"):
        def layer(h, lp):
            h = constrain(h, "batch", "model", None, tag="seqpar")   # sequence parallel
            hn = rms_norm(h, lp["attn_norm"], cfg.norm_eps)
            q, k, v = A.qkv_project(lp["attn"], hn, positions, cfg.attn,
                                    cfg.norm_eps)
            a = A.flash_attention(q, k, v, causal=True, window=win)
            h = h + a.reshape(B, T, -1) @ lp["attn"]["wo"]
            h, aux = _ffn_block(cfg, lp, h, policy, None, None,
                                capacity_factor, dispatch=dispatch)
            ck = _build_cache_slice(k, C, win).astype(cdt)
            cv = _build_cache_slice(v, C, win).astype(cdt)
            return h, (ck, cv, aux)
        x, (cks, cvs, aux) = jax.lax.scan(layer, x, params["layers"])
        cache["kv_k"], cache["kv_v"] = cks, cvs
    elif cfg.family == "ssm":
        def layer(h, lp):
            h = constrain(h, "batch", "model", None, tag="seqpar")   # sequence parallel
            hn = rms_norm(h, lp["norm"], cfg.norm_eps)
            y, (conv, state) = S.ssm_forward(lp["ssm"], hn, cfg.ssm,
                                             cfg.d_model, cfg.norm_eps)
            conv = tuple(c.astype(cdt) for c in conv)
            return h + y, (conv, state)
        x, (convs, states) = jax.lax.scan(layer, x, params["layers"])
        cache["conv_x"], cache["conv_B"], cache["conv_C"] = convs
        cache["state"] = states
        aux = {}
    elif cfg.family == "hybrid":
        ae = cfg.attn_every
        def layer(h, lp):
            h = constrain(h, "batch", "model", None, tag="seqpar")   # sequence parallel
            hn = rms_norm(h, lp["norm"], cfg.norm_eps)
            y, (conv, state) = S.ssm_forward(lp["ssm"], hn, cfg.ssm,
                                             cfg.d_model, cfg.norm_eps)
            conv = tuple(c.astype(cdt) for c in conv)
            return h + y, (conv, state)
        convs, states, sks, svs = [], [], [], []
        for g in range(_num_shared_apps(cfg)):
            hn = rms_norm(x, params["shared_attn"]["attn_norm"], cfg.norm_eps)
            q, k, v = A.qkv_project(params["shared_attn"]["attn"], hn,
                                    positions, cfg.attn, cfg.norm_eps)
            a = A.flash_attention(q, k, v, causal=True, window=win)
            x = x + a.reshape(B, T, -1) @ params["shared_attn"]["attn"]["wo"]
            hn = rms_norm(x, params["shared_attn"]["mlp_norm"], cfg.norm_eps)
            x = x + mlp_apply(params["shared_attn"]["mlp"], hn, cfg.act)
            sks.append(_build_cache_slice(k, C, win).astype(cdt))
            svs.append(_build_cache_slice(v, C, win).astype(cdt))
            lo, hi = g * ae, min((g + 1) * ae, cfg.num_layers)
            gp = jax.tree_util.tree_map(lambda t: t[lo:hi], params["layers"])
            x, (conv, state) = jax.lax.scan(layer, x, gp)
            convs.append(conv)
            states.append(state)
        cache["conv_x"] = jnp.concatenate([c[0] for c in convs], axis=0)
        cache["conv_B"] = jnp.concatenate([c[1] for c in convs], axis=0)
        cache["conv_C"] = jnp.concatenate([c[2] for c in convs], axis=0)
        cache["state"] = jnp.concatenate(states, axis=0)
        cache["shared_k"] = jnp.stack(sks, axis=0)
        cache["shared_v"] = jnp.stack(svs, axis=0)
        aux = {}
    else:
        raise ValueError(cfg.family)

    x_last = rms_norm(x[:, -1:], params["final_norm"], cfg.norm_eps)
    logits = lm_head_apply(cfg, params, x_last)[:, 0]
    return logits, cache, (aux if isinstance(aux, dict) else {})


# ---------------------------------------------------------------- decode --

def _ssm_decode_multi(lp, h: jnp.ndarray, conv, state, cfg: ArchConfig):
    """h: (B,T,d) -> (B,T,d), scanning the recurrence over T steps."""
    T = h.shape[1]
    if T == 1:
        y, (conv, state) = S.ssm_decode(lp, h[:, 0], (conv, state),
                                        cfg.ssm, cfg.d_model, cfg.norm_eps)
        return y[:, None], conv, state

    def step(c, xt):
        y, c2 = S.ssm_decode(lp, xt, c, cfg.ssm, cfg.d_model, cfg.norm_eps)
        return c2, y
    (conv, state), ys = jax.lax.scan(step, (conv, state),
                                     h.transpose(1, 0, 2))
    return ys.transpose(1, 0, 2), conv, state


def decode_step(cfg: ArchConfig, params, tokens: jnp.ndarray, cache: Dict, *,
                policy: XSharePolicy = OFF,
                spec_shape: Optional[Tuple[int, int]] = None,
                force_window: Optional[int] = None,
                capacity_factor: float = 2.0,
                active: Optional[jnp.ndarray] = None,
                dispatch: str = "auto",
                spec_priors: Optional[jnp.ndarray] = None):
    """Serve step: T new tokens per sequence (T=1 plain decode, T=1+L_s
    speculative verify). tokens: (B, T) (audio: (B,T,K)).

    active: optional (B,) bool — compute-mask for continuous batching:
    rows that are False (finished / empty slots) are excluded from MoE
    routing (no expert activation, no capacity consumption, no influence
    on XShare batch selection) and their aux metrics. Their logits are
    garbage the caller must ignore.

    spec_priors: optional (B, E) per-request gate-histogram priors for
    mode="spec" correlation-aware selection (see core/selection.py).

    Returns (logits (B,T,V[,K->(B,T,K,V)]), new cache, aux)."""
    x = embed_tokens(cfg, params, tokens)
    B, T = x.shape[:2]
    cur = jnp.asarray(cache["cur_len"])
    base = cur.reshape(-1, 1) if cur.ndim else jnp.full((B, 1), cur)
    positions = base + jnp.arange(T)[None, :]            # (B, T)
    win = effective_window(cfg, force_window=force_window)
    token_mask = None if active is None else \
        jnp.broadcast_to(active[:, None], (B, T))

    new_cache = dict(cache)
    if cfg.family in ("dense", "vlm", "audio", "moe"):
        def layer(h, xs):
            lp, ck, cv = xs
            h, ck, cv = _attn_block_decode(cfg, lp, h, positions, ck, cv,
                                           cur, win)
            h, aux = _ffn_block(cfg, lp, h, policy, spec_shape, None,
                                capacity_factor, token_mask, dispatch,
                                spec_priors)
            return h, (ck, cv, aux)
        x, (cks, cvs, aux) = jax.lax.scan(
            layer, x, (params["layers"], cache["kv_k"], cache["kv_v"]))
        new_cache["kv_k"], new_cache["kv_v"] = cks, cvs
    elif cfg.family == "ssm":
        def layer(h, xs):
            lp, conv, state = xs
            hn = rms_norm(h, lp["norm"], cfg.norm_eps)
            y, conv, state = _ssm_decode_multi(lp["ssm"], hn, conv, state,
                                               cfg)
            return h + y, (conv, state)
        x, (convs, states) = jax.lax.scan(
            layer, x, (params["layers"],
                       (cache["conv_x"], cache["conv_B"], cache["conv_C"]),
                       cache["state"]))
        (new_cache["conv_x"], new_cache["conv_B"],
         new_cache["conv_C"]) = convs
        new_cache["state"] = states
        aux = {}
    elif cfg.family == "hybrid":
        ae = cfg.attn_every
        def layer(h, xs):
            lp, conv, state = xs
            hn = rms_norm(h, lp["norm"], cfg.norm_eps)
            y, conv, state = _ssm_decode_multi(lp["ssm"], hn, conv, state,
                                               cfg)
            return h + y, (conv, state)
        convs, states, sks, svs = [], [], [], []
        for g in range(_num_shared_apps(cfg)):
            x, (sk, sv) = _shared_attn_block(
                cfg, params["shared_attn"], x, positions, win,
                cache=(cache["shared_k"][g], cache["shared_v"][g]),
                cur_len=cur)
            sks.append(sk)
            svs.append(sv)
            lo, hi = g * ae, min((g + 1) * ae, cfg.num_layers)
            gp = jax.tree_util.tree_map(lambda t: t[lo:hi], params["layers"])
            x, (conv, state) = jax.lax.scan(
                layer, x, (gp,
                           (cache["conv_x"][lo:hi], cache["conv_B"][lo:hi],
                            cache["conv_C"][lo:hi]),
                           cache["state"][lo:hi]))
            convs.append(conv)
            states.append(state)
        new_cache["conv_x"] = jnp.concatenate([c[0] for c in convs], axis=0)
        new_cache["conv_B"] = jnp.concatenate([c[1] for c in convs], axis=0)
        new_cache["conv_C"] = jnp.concatenate([c[2] for c in convs], axis=0)
        new_cache["state"] = jnp.concatenate(states, axis=0)
        new_cache["shared_k"] = jnp.stack(sks, axis=0)
        new_cache["shared_v"] = jnp.stack(svs, axis=0)
        aux = {}
    else:
        raise ValueError(cfg.family)

    new_cache["cur_len"] = cur + T
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = lm_head_apply(cfg, params, x)
    return logits, new_cache, (aux if isinstance(aux, dict) else {})
