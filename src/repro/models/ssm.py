"""Mamba2 (SSD — state-space duality) block. [arXiv:2405.21060]

Full-sequence processing uses the chunked SSD algorithm: intra-chunk
"attention-like" masked matmuls plus an inter-chunk state scan, giving
O(S * chunk) memory and matmul-dominated compute (MXU-friendly — this is
the TPU adaptation of the paper's CUDA scan). Decode is the O(1) SSM
recurrence over a (conv states, ssm_state) cache.

Projections are stored as separate matrices (z / x / B / C / dt) rather
than one fused in_proj: under tensor parallelism each output then shards
cleanly on its own axis (d_inner or group-state), with no cross-shard
slicing of a concatenated dimension — the fused layout would force a
resharding collective in every layer.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import SSMConfig
from repro.models.layers import dense_init, rms_norm


def dims(ssm: SSMConfig, d_model: int):
    d_inner = ssm.expand * d_model
    n_heads = d_inner // ssm.head_dim
    d_bc = ssm.n_groups * ssm.d_state
    return d_inner, n_heads, d_bc


def init_ssm(key, ssm: SSMConfig, d_model: int, dtype,
             stack: Optional[int] = None) -> Dict:
    d_inner, n_heads, d_bc = dims(ssm, d_model)
    pre = () if stack is None else (stack,)
    ks = jax.random.split(key, 9)
    K = ssm.d_conv
    return {
        "in_z": dense_init(ks[0], pre + (d_model, d_inner), dtype),
        "in_x": dense_init(ks[1], pre + (d_model, d_inner), dtype),
        "in_B": dense_init(ks[2], pre + (d_model, d_bc), dtype),
        "in_C": dense_init(ks[3], pre + (d_model, d_bc), dtype),
        "in_dt": dense_init(ks[4], pre + (d_model, n_heads), dtype),
        "conv_x_w": dense_init(ks[5], pre + (K, d_inner), dtype, scale=0.1),
        "conv_x_b": jnp.zeros(pre + (d_inner,), dtype),
        "conv_B_w": dense_init(ks[6], pre + (K, d_bc), dtype, scale=0.1),
        "conv_B_b": jnp.zeros(pre + (d_bc,), dtype),
        "conv_C_w": dense_init(ks[7], pre + (K, d_bc), dtype, scale=0.1),
        "conv_C_b": jnp.zeros(pre + (d_bc,), dtype),
        "A_log": jnp.zeros(pre + (n_heads,), jnp.float32),   # A = -1
        "D": jnp.ones(pre + (n_heads,), jnp.float32),
        "dt_bias": jnp.zeros(pre + (n_heads,), jnp.float32),
        "norm_w": jnp.ones(pre + (d_inner,), dtype),
        "out_proj": dense_init(ks[8], pre + (d_inner, d_model), dtype),
    }


def _causal_conv(u: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray,
                 init_state: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    """Depthwise causal conv1d + SiLU. u: (B,S,C), w: (K,C).

    init_state: (B, K-1, C) trailing pre-conv context from a previous
    segment (None = zeros, i.e. sequence start).
    """
    K = w.shape[0]
    if init_state is None:
        pad = jnp.zeros(u.shape[:1] + (K - 1,) + u.shape[2:], u.dtype)
    else:
        pad = init_state.astype(u.dtype)
    up = jnp.concatenate([pad, u], axis=1)
    out = sum(up[:, i:i + u.shape[1]] * w[i] for i in range(K)) + b
    return jax.nn.silu(out)


def _conv_tail(u: jnp.ndarray, K: int,
               init_state: Optional[jnp.ndarray]) -> jnp.ndarray:
    """Last K-1 pre-conv inputs (next segment's init_state)."""
    B = u.shape[0]
    if init_state is None:
        pad = jnp.zeros((B, K - 1) + u.shape[2:], u.dtype)
    else:
        pad = init_state.astype(u.dtype)
    return jnp.concatenate([pad, u], axis=1)[:, -(K - 1):]


def ssd_chunked(xh: jnp.ndarray, dt: jnp.ndarray, A: jnp.ndarray,
                Bm: jnp.ndarray, Cm: jnp.ndarray, chunk: int,
                init_state: Optional[jnp.ndarray] = None
                ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Chunked SSD scan.

    xh: (B,S,nh,hd) inputs; dt: (B,S,nh) post-softplus step sizes;
    A: (nh,) negative decay rates; Bm/Cm: (B,S,g,ds) input/output
    projections (g groups broadcast over heads).
    Returns y (B,S,nh,hd) and final state (B,nh,hd,ds).
    """
    Bsz, S, nh, hd = xh.shape
    g, ds = Bm.shape[2], Bm.shape[3]
    rep = nh // g
    l = min(chunk, S)
    Sp = ((S + l - 1) // l) * l
    if Sp != S:
        xh = jnp.pad(xh, ((0, 0), (0, Sp - S), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, Sp - S), (0, 0)))
        Bm = jnp.pad(Bm, ((0, 0), (0, Sp - S), (0, 0), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, Sp - S), (0, 0), (0, 0)))
    nc = Sp // l

    f32 = jnp.float32
    x = jnp.asarray(xh, f32).reshape(Bsz, nc, l, nh, hd)
    dt = jnp.asarray(dt, f32).reshape(Bsz, nc, l, nh)
    Bh = jnp.repeat(jnp.asarray(Bm, f32).reshape(Bsz, nc, l, g, ds),
                    rep, axis=3)                     # (B,nc,l,nh,ds)
    Ch = jnp.repeat(jnp.asarray(Cm, f32).reshape(Bsz, nc, l, g, ds),
                    rep, axis=3)
    dA = dt * A[None, None, None, :]                 # (B,nc,l,nh) <= 0
    cum = jnp.cumsum(dA, axis=2)                     # within-chunk cumsum

    # ---- intra-chunk (block-diagonal) term --------------------------------
    seg = cum[:, :, :, None, :] - cum[:, :, None, :, :]   # (B,nc,i,j,nh)
    tri = jnp.tril(jnp.ones((l, l), bool))
    decay = jnp.where(tri[None, None, :, :, None], jnp.exp(seg), 0.0)
    scores = jnp.einsum("bcihs,bcjhs->bcijh", Ch, Bh)     # (B,nc,i,j,nh)
    M = scores * decay * dt[:, :, None, :, :]             # fold dt_j
    y_diag = jnp.einsum("bcijh,bcjhp->bcihp", M, x)

    # ---- chunk states ------------------------------------------------------
    decay_states = jnp.exp(cum[:, :, -1:, :] - cum)       # (B,nc,l,nh)
    states = jnp.einsum("bclhs,bclh,bclhp->bchps",
                        Bh, decay_states * dt, x)         # (B,nc,nh,hd,ds)

    # ---- inter-chunk recurrence -------------------------------------------
    chunk_decay = jnp.exp(cum[:, :, -1, :])               # (B,nc,nh)
    s0 = jnp.zeros((Bsz, nh, hd, ds), f32) if init_state is None \
        else jnp.asarray(init_state, f32)

    def step(prev, inp):
        st, dec = inp                                     # (B,nh,hd,ds),(B,nh)
        new = prev * dec[:, :, None, None] + st
        return new, prev                                  # emit entering state

    final, entering = jax.lax.scan(
        step, s0,
        (states.transpose(1, 0, 2, 3, 4), chunk_decay.transpose(1, 0, 2)))
    entering = entering.transpose(1, 0, 2, 3, 4)          # (B,nc,nh,hd,ds)

    # ---- inter-chunk contribution -----------------------------------------
    state_decay = jnp.exp(cum)                            # decay 0..i
    y_off = jnp.einsum("bcihs,bchps,bcih->bcihp",
                       Ch, entering, state_decay)
    y = (y_diag + y_off).reshape(Bsz, Sp, nh, hd)[:, :S]
    return y.astype(xh.dtype), final


def ssm_forward(p: Dict, x: jnp.ndarray, ssm: SSMConfig, d_model: int,
                eps: float, *,
                init_conv: Optional[Tuple] = None,
                init_state: Optional[jnp.ndarray] = None,
                use_kernel: bool = False):
    """Full-sequence Mamba2 block. x: (B,S,d) -> (y (B,S,d), cache).

    cache = ((conv_x, conv_B, conv_C) pre-conv tails, ssm_state).
    """
    Bsz, S, _ = x.shape
    d_inner, nh, d_bc = dims(ssm, d_model)
    K = ssm.d_conv
    z = x @ p["in_z"]
    xr = x @ p["in_x"]
    Br = x @ p["in_B"]
    Cr = x @ p["in_C"]
    dt_raw = x @ p["in_dt"]
    ic = init_conv or (None, None, None)
    xc = _causal_conv(xr, p["conv_x_w"], p["conv_x_b"], ic[0])
    Bc = _causal_conv(Br, p["conv_B_w"], p["conv_B_b"], ic[1])
    Cc = _causal_conv(Cr, p["conv_C_w"], p["conv_C_b"], ic[2])
    xh = xc.reshape(Bsz, S, nh, ssm.head_dim)
    # keep the SSD intra-chunk intermediates (decay/score blocks carry an
    # nh axis) sharded over "model" — without this the (B,nc,l,l,nh)
    # tensors replicate and dominate HBM at 32k prefill
    from repro.sharding import constrain
    xh = constrain(xh, "batch", None, "model", None)
    Bm = Bc.reshape(Bsz, S, ssm.n_groups, ssm.d_state)
    Cm = Cc.reshape(Bsz, S, ssm.n_groups, ssm.d_state)
    dt = jax.nn.softplus(jnp.asarray(dt_raw, jnp.float32) + p["dt_bias"])
    dt = constrain(dt, "batch", None, "model")
    A = -jnp.exp(p["A_log"])
    if use_kernel:
        from repro.kernels.ops import ssd_chunk_scan
        assert init_state is None, "kernel path starts from zero state"
        rep = nh // ssm.n_groups
        y, final_state = ssd_chunk_scan(
            xh, dt, A, jnp.repeat(Bm, rep, 2), jnp.repeat(Cm, rep, 2),
            chunk=ssm.chunk_size)
    else:
        y, final_state = ssd_chunked(xh, dt, A, Bm, Cm, ssm.chunk_size,
                                     init_state)
    y = y + (p["D"][None, None, :, None] * jnp.asarray(xh, jnp.float32)
             ).astype(y.dtype)
    y = y.reshape(Bsz, S, d_inner)
    y = rms_norm(y * jax.nn.silu(z), p["norm_w"], eps)
    out = y @ p["out_proj"]
    conv_cache = (_conv_tail(xr, K, ic[0]), _conv_tail(Br, K, ic[1]),
                  _conv_tail(Cr, K, ic[2]))
    return out, (conv_cache, final_state)


def ssm_decode(p: Dict, x_t: jnp.ndarray, cache, ssm: SSMConfig,
               d_model: int, eps: float):
    """Single-token recurrence. x_t: (B, d);
    cache = ((conv_x, conv_B, conv_C), ssm_state)."""
    (cx, cB, cC), ssm_state = cache
    Bsz = x_t.shape[0]
    d_inner, nh, d_bc = dims(ssm, d_model)
    z = x_t @ p["in_z"]
    xr = x_t @ p["in_x"]
    Br = x_t @ p["in_B"]
    Cr = x_t @ p["in_C"]
    dt_raw = x_t @ p["in_dt"]

    def conv1(state, new, w, b):
        win = jnp.concatenate([state, new[:, None].astype(state.dtype)],
                              axis=1)                     # (B,K,C)
        out = jax.nn.silu(jnp.einsum(
            "bkc,kc->bc", jnp.asarray(win, jnp.float32),
            jnp.asarray(w, jnp.float32)) + b).astype(x_t.dtype)
        return out, win[:, 1:]

    xc, cx = conv1(cx, xr, p["conv_x_w"], p["conv_x_b"])
    Bc, cB = conv1(cB, Br, p["conv_B_w"], p["conv_B_b"])
    Cc, cC = conv1(cC, Cr, p["conv_C_w"], p["conv_C_b"])

    xh = jnp.asarray(xc.reshape(Bsz, nh, ssm.head_dim), jnp.float32)
    rep = nh // ssm.n_groups
    Bm = jnp.repeat(jnp.asarray(
        Bc.reshape(Bsz, ssm.n_groups, ssm.d_state), jnp.float32),
        rep, axis=1)                                      # (B,nh,ds)
    Cm = jnp.repeat(jnp.asarray(
        Cc.reshape(Bsz, ssm.n_groups, ssm.d_state), jnp.float32),
        rep, axis=1)
    dt = jax.nn.softplus(jnp.asarray(dt_raw, jnp.float32) + p["dt_bias"])
    A = -jnp.exp(p["A_log"])
    dA = jnp.exp(dt * A)                                  # (B,nh)
    new_state = (jnp.asarray(ssm_state, jnp.float32)
                 * dA[:, :, None, None]
                 + jnp.einsum("bh,bhp,bhs->bhps", dt, xh, Bm))
    y = jnp.einsum("bhs,bhps->bhp", Cm, new_state)
    y = y + p["D"][None, :, None] * xh
    y = y.reshape(Bsz, d_inner).astype(x_t.dtype)
    y = rms_norm(y * jax.nn.silu(z), p["norm_w"], eps)
    out = y @ p["out_proj"]
    return out, ((cx, cB, cC), new_state.astype(ssm_state.dtype))
