"""Attention: GQA + RoPE, chunked (flash-style) full-sequence attention for
train/prefill, and cache-based attention for decode / speculative verify.

Full-sequence attention is a double lax.scan over (q-chunk, kv-chunk) with
online softmax, so peak memory is O(S * chunk) instead of O(S^2) — required
for the 32k prefill shape. Decode attention scores one (or a few verify)
tokens against a full or rolling-window KV cache; with the cache sequence
axis sharded over the mesh "model" axis, XLA SPMD turns the softmax
normalizer into a cross-shard reduction (flash-decode).
"""
from __future__ import annotations

import functools
import math
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import AttnConfig
from repro.models.layers import dense_init, rms_norm

_NEG = -1e30


# ----------------------------------------------------------------- RoPE ---

def apply_rope(x: jnp.ndarray, positions: jnp.ndarray,
               theta: float) -> jnp.ndarray:
    """x: (B, S, H, dh), positions: (B, S) or (S,) int32."""
    dh = x.shape[-1]
    half = dh // 2
    freqs = 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) / half))
    if positions.ndim == 1:
        positions = positions[None, :]
    ang = positions[..., None].astype(jnp.float32) * freqs  # (B,S,half)
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    x1, x2 = x[..., :half], x[..., half:]
    xf1, xf2 = jnp.asarray(x1, jnp.float32), jnp.asarray(x2, jnp.float32)
    out = jnp.concatenate([xf1 * cos - xf2 * sin,
                           xf2 * cos + xf1 * sin], axis=-1)
    return out.astype(x.dtype)


# --------------------------------------------------------------- params ---

def init_attn(key, attn: AttnConfig, d_model: int, dtype,
              stack: Optional[int] = None) -> Dict:
    pre = () if stack is None else (stack,)
    ks = jax.random.split(key, 4)
    H, Hkv, dh = attn.num_heads, attn.num_kv_heads, attn.head_dim
    p = {
        "wq": dense_init(ks[0], pre + (d_model, H * dh), dtype),
        "wk": dense_init(ks[1], pre + (d_model, Hkv * dh), dtype),
        "wv": dense_init(ks[2], pre + (d_model, Hkv * dh), dtype),
        "wo": dense_init(ks[3], pre + (H * dh, d_model), dtype),
    }
    if attn.qk_norm:
        p["q_norm"] = jnp.ones(pre + (dh,), dtype)
        p["k_norm"] = jnp.ones(pre + (dh,), dtype)
    return p


def qkv_project(p: Dict, x: jnp.ndarray, positions: jnp.ndarray,
                attn: AttnConfig, eps: float = 1e-6
                ) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """x: (B, S, d) -> q (B,S,H,dh), k/v (B,S,Hkv,dh), rope applied."""
    B, S, _ = x.shape
    H, Hkv, dh = attn.num_heads, attn.num_kv_heads, attn.head_dim
    q = (x @ p["wq"]).reshape(B, S, H, dh)
    k = (x @ p["wk"]).reshape(B, S, Hkv, dh)
    v = (x @ p["wv"]).reshape(B, S, Hkv, dh)
    if attn.qk_norm:
        q = rms_norm(q, p["q_norm"], eps)
        k = rms_norm(k, p["k_norm"], eps)
    q = apply_rope(q, positions, attn.rope_theta)
    k = apply_rope(k, positions, attn.rope_theta)
    return q, k, v


# ------------------------------------------------- full-seq (prefill) -----
#
# Chunked (flash) attention with a CUSTOM VJP: the backward pass
# recomputes the per-block probability matrix from (q, k, lse) instead of
# letting autodiff save every scan iteration's residuals — without this,
# the 4k-train / 32k-prefill shapes store O(S^2 / chunk) per layer and
# blow past HBM.


def _block_mask(row, col, S: int, causal: bool, window: Optional[int]):
    mask = col[None, :] < S                       # drop kv padding
    if causal:
        mask = mask & (col[None, :] <= row[:, None])
    if window is not None:
        mask = mask & (col[None, :] > row[:, None] - window)
    return mask


def _flash_fwd(q, k, v, *, causal, window, q_chunk, kv_chunk, true_s):
    """Returns (out (B,S,H,dh), lse (B,H,S)) — padded inputs."""
    B, Sp, H, dh = q.shape
    Sk = k.shape[1]
    Hkv = k.shape[2]
    rep = H // Hkv
    scale = 1.0 / math.sqrt(dh)
    nq, nk = Sp // q_chunk, Sk // kv_chunk
    S = true_s

    qs = q.reshape(B, nq, q_chunk, H, dh).transpose(1, 0, 2, 3, 4)
    ks = k.reshape(B, nk, kv_chunk, Hkv, dh).transpose(1, 0, 2, 3, 4)
    vs = v.reshape(B, nk, kv_chunk, Hkv, dh).transpose(1, 0, 2, 3, 4)

    def q_step(_, qi):
        qb, i = qi
        qbf = jnp.asarray(qb, jnp.float32)
        row = i * q_chunk + jnp.arange(q_chunk)

        def kv_step(carry, kj):
            m, l, acc = carry
            kb, vb, j = kj
            col = j * kv_chunk + jnp.arange(kv_chunk)
            kbf = jnp.repeat(jnp.asarray(kb, jnp.float32), rep, axis=2)
            vbf = jnp.repeat(jnp.asarray(vb, jnp.float32), rep, axis=2)
            s = jnp.einsum("bqhd,bkhd->bhqk", qbf, kbf) * scale
            mask = _block_mask(row, col, S, causal, window)
            maskf = mask.astype(jnp.float32)
            s = jnp.where(mask[None, None], s, _NEG)
            m_new = jnp.maximum(m, s.max(axis=-1))
            p = jnp.exp(s - m_new[..., None]) * maskf[None, None]
            alpha = jnp.exp(m - m_new)
            acc = acc * alpha[..., None] + jnp.einsum(
                "bhqk,bkhd->bhqd", p, vbf)
            l = l * alpha + p.sum(axis=-1)
            return (m_new, l, acc), None

        m0 = jnp.full((B, H, q_chunk), _NEG, jnp.float32)
        l0 = jnp.zeros((B, H, q_chunk), jnp.float32)
        a0 = jnp.zeros((B, H, q_chunk, dh), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(kv_step, (m0, l0, a0),
                                      (ks, vs, jnp.arange(nk)))
        out = acc / jnp.maximum(l, 1e-30)[..., None]
        lse = m + jnp.log(jnp.maximum(l, 1e-30))      # (B,H,qc)
        return None, (out.transpose(0, 2, 1, 3), lse)

    _, (outs, lses) = jax.lax.scan(q_step, None, (qs, jnp.arange(nq)))
    out = outs.transpose(1, 0, 2, 3, 4).reshape(B, Sp, H, dh)
    lse = lses.transpose(1, 2, 0, 3).reshape(B, H, Sp)
    return out.astype(q.dtype), lse


def _flash_bwd(q, k, v, out, lse, g, *, causal, window, q_chunk, kv_chunk,
               true_s):
    """Blockwise flash-attention backward (recompute p from lse)."""
    B, Sp, H, dh = q.shape
    Sk, Hkv = k.shape[1], k.shape[2]
    rep = H // Hkv
    scale = 1.0 / math.sqrt(dh)
    nq, nk = Sp // q_chunk, Sk // kv_chunk
    S = true_s

    f32 = jnp.float32
    D = jnp.einsum("bshd,bshd->bhs", jnp.asarray(g, f32),
                   jnp.asarray(out, f32))            # (B,H,Sp)

    qs = q.reshape(B, nq, q_chunk, H, dh).transpose(1, 0, 2, 3, 4)
    gs = g.reshape(B, nq, q_chunk, H, dh).transpose(1, 0, 2, 3, 4)
    ls = lse.reshape(B, H, nq, q_chunk).transpose(2, 0, 1, 3)
    Ds = D.reshape(B, H, nq, q_chunk).transpose(2, 0, 1, 3)
    ks = k.reshape(B, nk, kv_chunk, Hkv, dh).transpose(1, 0, 2, 3, 4)
    vs = v.reshape(B, nk, kv_chunk, Hkv, dh).transpose(1, 0, 2, 3, 4)

    def q_step(carry, qi):
        dk_full, dv_full = carry                     # (B,Sk,Hkv,dh) f32
        qb, gb, lse_b, D_b, i = qi
        qbf = jnp.asarray(qb, f32)
        gbf = jnp.asarray(gb, f32)
        row = i * q_chunk + jnp.arange(q_chunk)

        def kv_step(carry2, kj):
            dq_blk, dk_full, dv_full = carry2
            kb, vb, j = kj
            col = j * kv_chunk + jnp.arange(kv_chunk)
            kbf = jnp.repeat(jnp.asarray(kb, f32), rep, axis=2)
            vbf = jnp.repeat(jnp.asarray(vb, f32), rep, axis=2)
            s = jnp.einsum("bqhd,bkhd->bhqk", qbf, kbf) * scale
            mask = _block_mask(row, col, S, causal, window)
            p = jnp.exp(s - lse_b[..., None]) * \
                mask[None, None].astype(f32)          # (B,H,q,k)
            dp = jnp.einsum("bqhd,bkhd->bhqk", gbf, vbf)
            ds = p * (dp - D_b[..., None]) * scale    # (B,H,q,k)
            dq_blk += jnp.einsum("bhqk,bkhd->bqhd", ds, kbf)
            dv_b = jnp.einsum("bhqk,bqhd->bkhd", p, gbf)
            dk_b = jnp.einsum("bhqk,bqhd->bkhd", ds, qbf)
            # fold grouped heads back to kv heads
            dv_b = dv_b.reshape(B, kv_chunk, Hkv, rep, dh).sum(3)
            dk_b = dk_b.reshape(B, kv_chunk, Hkv, rep, dh).sum(3)
            dk_full = jax.lax.dynamic_update_slice(
                dk_full, jax.lax.dynamic_slice(
                    dk_full, (0, j * kv_chunk, 0, 0),
                    (B, kv_chunk, Hkv, dh)) + dk_b,
                (0, j * kv_chunk, 0, 0))
            dv_full = jax.lax.dynamic_update_slice(
                dv_full, jax.lax.dynamic_slice(
                    dv_full, (0, j * kv_chunk, 0, 0),
                    (B, kv_chunk, Hkv, dh)) + dv_b,
                (0, j * kv_chunk, 0, 0))
            return (dq_blk, dk_full, dv_full), None

        dq0 = jnp.zeros((B, q_chunk, H, dh), f32)
        (dq_blk, dk_full, dv_full), _ = jax.lax.scan(
            kv_step, (dq0, dk_full, dv_full), (ks, vs, jnp.arange(nk)))
        return (dk_full, dv_full), dq_blk

    dk0 = jnp.zeros((B, Sk, Hkv, dh), f32)
    dv0 = jnp.zeros((B, Sk, Hkv, dh), f32)
    (dk, dv), dqs = jax.lax.scan(
        q_step, (dk0, dv0), (qs, gs, ls, Ds, jnp.arange(nq)))
    dq = dqs.transpose(1, 0, 2, 3, 4).reshape(B, Sp, H, dh)
    return dq.astype(q.dtype), dk.astype(k.dtype), dv.astype(v.dtype)


@functools.lru_cache(maxsize=None)
def _flash_fn(causal: bool, window: Optional[int], q_chunk: int,
              kv_chunk: int, true_s: int):
    @jax.custom_vjp
    def f(q, k, v):
        out, _ = _flash_fwd(q, k, v, causal=causal, window=window,
                            q_chunk=q_chunk, kv_chunk=kv_chunk,
                            true_s=true_s)
        return out

    def fwd(q, k, v):
        out, lse = _flash_fwd(q, k, v, causal=causal, window=window,
                              q_chunk=q_chunk, kv_chunk=kv_chunk,
                              true_s=true_s)
        return out, (q, k, v, out, lse)

    def bwd(res, g):
        q, k, v, out, lse = res
        return _flash_bwd(q, k, v, out, lse, g, causal=causal,
                          window=window, q_chunk=q_chunk,
                          kv_chunk=kv_chunk, true_s=true_s)

    f.defvjp(fwd, bwd)
    return f


def flash_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                    causal: bool = True, window: Optional[int] = None,
                    q_chunk: int = 512, kv_chunk: int = 512) -> jnp.ndarray:
    """Chunked causal attention with online softmax and O(S*chunk)
    memory in both directions (custom VJP).

    q: (B, S, H, dh); k, v: (B, S, Hkv, dh) with H % Hkv == 0.
    window: sliding-window width (attend to the last `window` positions,
    inclusive of self). Returns (B, S, H, dh).
    """
    B, S, H, dh = q.shape
    qc = min(q_chunk, S)
    kc = min(kv_chunk, S)
    Sp = ((S + qc - 1) // qc) * qc
    Sk = ((S + kc - 1) // kc) * kc
    if Sp != S:
        q = jnp.pad(q, ((0, 0), (0, Sp - S), (0, 0), (0, 0)))
    if Sk != S:
        k = jnp.pad(k, ((0, 0), (0, Sk - S), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, Sk - S), (0, 0), (0, 0)))
    fn = _flash_fn(causal, window, qc, kc, S)
    out = fn(q, k, v)
    return out[:, :S]


# ------------------------------------------------------- cache (decode) ---

def cached_attention(q: jnp.ndarray, cache_k: jnp.ndarray,
                     cache_v: jnp.ndarray, cur_len: jnp.ndarray, *,
                     window: Optional[int] = None,
                     start_pos: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    """Score new tokens against an (already updated) KV cache.

    q: (B, T, H, dh) — T new tokens whose k/v were written at positions
    [cur_len, cur_len+T). cache_k/v: (B, C, Hkv, dh). cur_len may be a
    scalar or a per-row (B,) vector (ragged speculative acceptance).
    For a full cache, slot c holds position c; for a rolling window cache
    (C >= window + spec margin), slot c holds the latest position
    p < cur_len+T with p % C == c. Returns (B, T, H, dh).
    """
    B, T, H, dh = q.shape
    C, Hkv = cache_k.shape[1], cache_k.shape[2]
    rep = H // Hkv
    scale = 1.0 / math.sqrt(dh)
    # grouped-query einsums against the cache IN ITS STORED DTYPE, dot
    # output in the same dtype (softmax is still f32): an explicit
    # preferred_element_type=f32 here makes XLA materialize a convert of
    # the whole (stacked, loop-hoisted) cache to f32 — doubling decode
    # HBM residency. bf16 score rounding is the standard serving
    # trade-off; unit tests run the whole path in f32.
    ck = cache_k
    cv = cache_v
    if ck.dtype.itemsize < 2:          # quantized (f8) cache: dequant
        ck = ck.astype(jnp.bfloat16)   # per-use (on TPU: per VMEM block)
        cv = cv.astype(jnp.bfloat16)
    qg = q.reshape(B, T, Hkv, rep, dh).astype(ck.dtype)
    s = jnp.einsum("btgrd,bcgd->bgrtc", qg, ck)
    s = jnp.asarray(s, jnp.float32).reshape(B, H, T, C) * scale
    slot = jnp.arange(C)[None, None, :]                  # (1,1,C)
    cur = jnp.asarray(cur_len)
    cur_b = jnp.broadcast_to(cur.reshape(-1, 1), (B, 1)) if cur.ndim \
        else jnp.full((B, 1), cur)
    q_pos = (cur_b + jnp.arange(T)[None, :])[..., None]  # (B,T,1)
    if window is None:
        slot_pos = jnp.broadcast_to(slot, (B, T, C))
    else:
        # latest position written to each slot, per query token
        slot_pos = q_pos - ((q_pos - slot) % C)
    mask = slot_pos <= q_pos
    mask = mask & (slot_pos >= 0)
    if window is not None:
        mask = mask & (slot_pos > q_pos - window)
    if start_pos is not None:
        mask = mask & (slot_pos >= start_pos)
    maskf = mask.astype(jnp.float32)
    s = jnp.where(mask[:, None], s, _NEG)
    m = s.max(axis=-1, keepdims=True)
    p = jnp.exp(s - m) * maskf[:, None]
    pg = p.reshape(B, Hkv, rep, T, C).astype(cv.dtype)
    out = jnp.einsum("bgrtc,bcgd->btgrd", pg, cv)
    out = jnp.asarray(out, jnp.float32).reshape(B, T, H, dh)
    denom = p.sum(axis=-1)[..., None].transpose(0, 2, 1, 3)
    out = out / jnp.maximum(denom, 1e-30)
    return out.astype(q.dtype)


def update_cache(cache: jnp.ndarray, new: jnp.ndarray, cur_len: jnp.ndarray,
                 *, window: Optional[int] = None) -> jnp.ndarray:
    """Write T new per-token kv rows at positions [cur_len, cur_len+T).

    cache: (B, C, Hkv, dh); new: (B, T, Hkv, dh). Rolling-window caches
    wrap modulo C; full caches assume cur_len+T <= C. cur_len may be a
    scalar or per-row (B,).

    Implemented as a select against slot-index masks rather than a
    scatter: a dynamic scatter into the (sharded) cache sequence axis
    forces SPMD to replicate the whole cache ("involuntary full
    rematerialization"); the where-form is purely elementwise and keeps
    the cache sharded in place.
    """
    B, T = new.shape[0], new.shape[1]
    C = cache.shape[1]
    cur = jnp.asarray(cur_len)
    cur_b = jnp.broadcast_to(cur.reshape(-1, 1), (B, 1)) if cur.ndim \
        else jnp.full((B, 1), cur)
    slot = jnp.arange(C)[None, :]                        # (1, C)
    out = cache
    newc = new.astype(cache.dtype)
    for i in range(T):                                   # T is small/static
        pos = cur_b + i                                  # (B, 1)
        if window is not None:
            pos = pos % C
        hit = (slot == pos)[:, :, None, None]            # (B, C, 1, 1)
        out = jnp.where(hit, newc[:, i:i + 1], out)
    return out
