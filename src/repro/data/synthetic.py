"""Synthetic language-modeling data pipeline.

Offline container => no AIME/GPQA/etc. To reproduce the paper's
*heterogeneous dataset* experiments (Sec 6.3, Fig 3) we need token streams
whose distributions differ systematically between "datasets" while staying
learnable: each named dataset is a distinct first-order Markov chain over
a zipf-weighted vocabulary, seeded deterministically from the dataset
name. Tokens within one request are correlated (same chain state), tokens
across datasets use different transition structure — mirroring "requests
drawn from heterogeneous datasets".
"""
from __future__ import annotations

import hashlib
from typing import Dict, Iterator, List, Sequence

import numpy as np


def _seed_of(name: str) -> int:
    return int.from_bytes(hashlib.sha256(name.encode()).digest()[:4], "big")


class SyntheticLM:
    """First-order Markov LM over a zipf vocabulary.

    Sparse transitions: each token has `branch` plausible successors, so
    sequences carry real structure a small model can learn (needed for the
    accuracy-proxy benchmarks).
    """

    def __init__(self, vocab_size: int, *, name: str = "default",
                 branch: int = 16, zipf_a: float = 1.2):
        self.vocab_size = vocab_size
        self.name = name
        rng = np.random.default_rng(_seed_of(name))
        ranks = np.arange(1, vocab_size + 1, dtype=np.float64)
        base = ranks ** (-zipf_a)
        base /= base.sum()
        # dataset-specific marginal: permute which tokens are frequent —
        # heterogeneous "domains" then activate distinct expert sets
        # (the Sec 6.3 / Fig 3 structure)
        base = base[rng.permutation(vocab_size)]
        # per-token successor sets + weights
        self.succ = rng.choice(vocab_size, size=(vocab_size, branch),
                               p=base)
        w = rng.dirichlet(np.ones(branch) * 0.5, size=vocab_size)
        self.succ_w = w
        self.base = base

    def sample(self, rng: np.random.Generator, batch: int,
               seq_len: int) -> np.ndarray:
        out = np.empty((batch, seq_len), np.int32)
        cur = rng.choice(self.vocab_size, size=batch, p=self.base)
        out[:, 0] = cur
        for t in range(1, seq_len):
            rows = self.succ[cur]                       # (B, branch)
            ws = self.succ_w[cur]
            pick = (ws.cumsum(-1) > rng.random((batch, 1))).argmax(-1)
            cur = rows[np.arange(batch), pick].astype(np.int32)
            out[:, t] = cur
        return out


def make_dataset_family(vocab_size: int,
                        names: Sequence[str]) -> Dict[str, SyntheticLM]:
    """Named heterogeneous "datasets" (gpqa/aime/mmlu-pro/aa-lcr stand-ins)."""
    return {n: SyntheticLM(vocab_size, name=n) for n in names}


def batches(lm: SyntheticLM, *, batch: int, seq_len: int, seed: int = 0,
            num_codebooks: int = 1) -> Iterator[np.ndarray]:
    """Endless stream of (B, S) int32 batches ((B, S, K) for audio)."""
    rng = np.random.default_rng(seed)
    while True:
        if num_codebooks == 1:
            yield lm.sample(rng, batch, seq_len)
        else:
            yield np.stack([lm.sample(rng, batch, seq_len)
                            for _ in range(num_codebooks)], axis=-1)


def mixed_request_batch(lms: Dict[str, SyntheticLM], *, seq_len: int,
                        seed: int = 0) -> np.ndarray:
    """One request per dataset — the paper's Sec 6.3 mixed batch."""
    rng = np.random.default_rng(seed)
    return np.concatenate([lm.sample(rng, 1, seq_len)
                           for lm in lms.values()], axis=0)
