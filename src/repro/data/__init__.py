from repro.data.synthetic import (  # noqa: F401
    SyntheticLM, make_dataset_family, batches, mixed_request_batch,
)
