"""Durable request journal + engine snapshots for crash-tolerant serving.

The front door (serving/frontdoor.py) survives a process kill with two
on-disk artifacts:

  * **journal** — an append-only write-ahead log of request lifecycle
    records (``submit`` / ``admit`` / ``token`` / ``finish`` / ``cancel``
    / ``snapshot`` / ``drain``). Each record is framed as

        [u32 payload length][u32 crc32(payload)][payload (compact JSON)]

    so the reader can detect — and cleanly stop at — a torn final
    record after a crash. Writes are **fsync-batched**: token records
    buffer in memory and hit the disk every ``fsync_every`` records;
    lifecycle records (submit/finish/cancel/snapshot/drain) are synced
    immediately. ``abandon()`` models the crash itself: the buffered
    tail is *lost* (optionally leaving a torn prefix of the next
    record, as a real torn write would), which is exactly the loss
    profile recovery must tolerate.

  * **snapshot** — a periodic checkpoint of the *logical* engine state
    built on checkpoint/ckpt.py: per-request prompts + durably emitted
    tokens, queue order, scheduler RNG key, per-slot rid/cur_len table,
    and counters. Model params are referenced (by the recovering
    engine), never copied. Snapshots are written to a temp file and
    ``os.replace``d so a crash mid-snapshot never corrupts the last
    good one.

Recovery folds the snapshot and then the journal tail into one request
table (``fold_records``). Token records carry their absolute start
index, so applying them is idempotent — replaying the full journal over
a snapshot (or over a previous recovery's re-journaled tokens) always
converges to the same table.
"""
from __future__ import annotations

import json
import logging
import os
import struct
import threading
import zlib
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Tuple

import numpy as np

from repro.checkpoint.ckpt import load_checkpoint, save_checkpoint

logger = logging.getLogger(__name__)

_HEADER = struct.Struct("<II")          # (payload length, crc32)

# record types that fsync immediately (token records batch)
DURABLE_NOW = frozenset({"submit", "finish", "cancel", "snapshot", "drain"})


# ------------------------------------------------------------- writer ------

class JournalWriter:
    """Append-only CRC-framed journal with batched fsync.

    ``append()`` buffers the encoded record; the buffer is written +
    fsync'd when it holds ``fsync_every`` records or when a
    lifecycle-critical record type (DURABLE_NOW) lands. A record is
    **durable** only once flushed — ``abandon()`` (simulated crash)
    drops the buffered tail exactly like a real kill would.

    Thread-safe: the front door appends from caller threads (submit /
    cancel) and from the serving thread (token / finish / snapshot)
    concurrently, so every mutation holds an internal lock — without it
    an append landing between flush()'s write and its buffer clear
    would be silently dropped even though append() reported it durable.
    """

    def __init__(self, path: str, *, fsync_every: int = 8,
                 start_seq: int = 0):
        os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
        self.path = path
        self.fsync_every = fsync_every
        self._lock = threading.RLock()      # append() flushes re-entrantly
        self._f: Optional[Any] = open(path, "ab")
        self._pending: List[bytes] = []
        self._seq = start_seq
        self.records_flushed = 0
        self.syncs = 0

    @property
    def seq(self) -> int:
        """Sequence number the next record will carry."""
        with self._lock:
            return self._seq

    @property
    def closed(self) -> bool:
        return self._f is None

    def append(self, rtype: str, **fields) -> int:
        """Buffer one record; flush per the fsync policy. Returns seq."""
        with self._lock:
            if self._f is None:
                raise ValueError("journal is closed")
            rec = {"seq": self._seq, "t": rtype, **fields}
            seq = self._seq
            self._seq += 1
            payload = json.dumps(rec, separators=(",", ":")).encode()
            self._pending.append(
                _HEADER.pack(len(payload), zlib.crc32(payload)) + payload)
            if rtype in DURABLE_NOW or len(self._pending) >= self.fsync_every:
                self.flush()
            return seq

    def flush(self) -> None:
        """Write + fsync everything buffered (records become durable)."""
        with self._lock:
            if self._f is None:
                return
            if self._pending:
                self._f.write(b"".join(self._pending))
                self.records_flushed += len(self._pending)
                self._pending.clear()
            self._f.flush()
            os.fsync(self._f.fileno())
            self.syncs += 1

    def abandon(self, *, torn_bytes: int = 0) -> int:
        """Simulated crash: the buffered tail is LOST. With
        ``torn_bytes > 0`` a strict prefix of the first unflushed record
        is left on disk — the torn-write the reader must tolerate.
        Returns the number of records dropped."""
        with self._lock:
            dropped = len(self._pending)
            if self._f is not None:
                if torn_bytes > 0 and self._pending:
                    frag = self._pending[0][:max(
                        1, min(torn_bytes, len(self._pending[0]) - 1))]
                    self._f.write(frag)
                    self._f.flush()
                    os.fsync(self._f.fileno())
                self._pending.clear()
                self._f.close()
                self._f = None
            return dropped

    def close(self) -> None:
        with self._lock:
            if self._f is not None:
                self.flush()
                self._f.close()
                self._f = None


# ------------------------------------------------------------- reader ------

@dataclass
class JournalTail:
    """Everything recoverable from a journal file."""
    records: List[Dict]
    torn: bool = False            # file ended in a truncated/corrupt record
    valid_bytes: int = 0          # offset of the last intact record's end

    @property
    def last_seq(self) -> int:
        return self.records[-1]["seq"] if self.records else -1


def read_journal(path: str) -> JournalTail:
    """Read every intact record; tolerate a torn tail.

    A truncated header, truncated payload, CRC mismatch, or undecodable
    payload in the FINAL position is the signature of a crash mid-write:
    it is logged and skipped (``torn=True``) instead of crashing
    recovery. Everything before it is returned.
    """
    if not os.path.exists(path):
        return JournalTail(records=[])
    with open(path, "rb") as f:
        data = f.read()
    records: List[Dict] = []
    off, torn = 0, False
    while off < len(data):
        if off + _HEADER.size > len(data):
            torn = True
            break
        length, crc = _HEADER.unpack_from(data, off)
        start, end = off + _HEADER.size, off + _HEADER.size + length
        if end > len(data):
            torn = True
            break
        payload = data[start:end]
        if zlib.crc32(payload) != crc:
            torn = True
            break
        try:
            records.append(json.loads(payload))
        except (ValueError, UnicodeDecodeError):
            torn = True
            break
        off = end
    if torn:
        logger.warning(
            "journal %s: torn tail at byte %d/%d — %d intact records "
            "recovered, truncated final record skipped",
            path, off, len(data), len(records))
    return JournalTail(records=records, torn=torn, valid_bytes=off)


# ----------------------------------------------------------- snapshots -----

@dataclass
class Snapshot:
    """Logical engine state at a point in time (params NOT included —
    they are referenced by the recovering engine)."""
    requests: Dict[int, Dict] = field(default_factory=dict)
    # rid -> {"prompt": np.ndarray, "tokens": list, "max_new": int,
    #         "reason": Optional[str], "arrival_s": float}
    queue: List[int] = field(default_factory=list)     # non-terminal rids
    rng_key: Optional[np.ndarray] = None               # scheduler PRNG key
    slot_rids: Optional[np.ndarray] = None             # (S,) int, -1 empty
    slot_cur_len: Optional[np.ndarray] = None          # (S,) int
    next_rid: int = 0
    seq: int = 0                # journal seq this snapshot subsumes
    total_steps: int = 0
    round_idx: int = 0


def save_snapshot(path: str, snap: Snapshot) -> None:
    """Atomic snapshot write via checkpoint/ckpt.py (tmp + os.replace):
    a crash mid-write never clobbers the previous good snapshot."""
    arrays: Dict[str, np.ndarray] = {}
    meta_reqs: Dict[str, Dict] = {}
    for rid, r in snap.requests.items():
        arrays[f"prompt_{rid}"] = np.asarray(r["prompt"])
        arrays[f"tokens_{rid}"] = np.asarray(r["tokens"], np.int32) \
            if len(r["tokens"]) else np.zeros((0,), np.int32)
        meta_reqs[str(rid)] = {
            "max_new": int(r["max_new"]),
            "reason": r.get("reason"),
            "arrival_s": float(r.get("arrival_s", 0.0)),
            "spec": bool(r.get("spec", False)),
        }
    if snap.rng_key is not None:
        arrays["rng_key"] = np.asarray(snap.rng_key)
    if snap.slot_rids is not None:
        arrays["slot_rids"] = np.asarray(snap.slot_rids, np.int64)
        arrays["slot_cur_len"] = np.asarray(snap.slot_cur_len, np.int64)
    extra = {
        "kind": "xshare-serving-snapshot",
        "requests": meta_reqs,
        "queue": [int(r) for r in snap.queue],
        "next_rid": int(snap.next_rid),
        "seq": int(snap.seq),
        "total_steps": int(snap.total_steps),
        "round_idx": int(snap.round_idx),
    }
    base = path[:-4] if path.endswith(".npz") else path
    tmp = base + ".tmp"
    save_checkpoint(tmp, arrays, step=snap.round_idx, extra=extra)
    os.replace(tmp + ".npz", base + ".npz")
    os.replace(tmp + ".json", base + ".json")


def load_snapshot(path: str) -> Optional[Snapshot]:
    """Load a snapshot; None (logged) if absent or unreadable — recovery
    then proceeds from the journal alone."""
    base = path[:-4] if path.endswith(".npz") else path
    if not os.path.exists(base + ".npz"):
        return None
    try:
        arrays, meta = load_checkpoint(base)
    except Exception as e:                     # corrupt snapshot: skip it
        logger.warning("snapshot %s unreadable (%s) — recovering from "
                       "the journal alone", path, e)
        return None
    extra = meta.get("extra", {})
    snap = Snapshot(
        queue=[int(r) for r in extra.get("queue", [])],
        next_rid=int(extra.get("next_rid", 0)),
        seq=int(extra.get("seq", 0)),
        total_steps=int(extra.get("total_steps", 0)),
        round_idx=int(extra.get("round_idx", 0)),
        rng_key=arrays.get("rng_key"),
        slot_rids=arrays.get("slot_rids"),
        slot_cur_len=arrays.get("slot_cur_len"),
    )
    for rid_s, m in extra.get("requests", {}).items():
        rid = int(rid_s)
        toks = arrays.get(f"tokens_{rid}")
        snap.requests[rid] = {
            "prompt": arrays[f"prompt_{rid}"],
            "tokens": [] if toks is None or toks.size == 0
            else [t for t in np.asarray(toks)],
            "max_new": int(m["max_new"]),
            "reason": m.get("reason"),
            "arrival_s": float(m.get("arrival_s", 0.0)),
            "spec": bool(m.get("spec", False)),
        }
    return snap


# ------------------------------------------------------------- folding -----

def fold_records(records: List[Dict],
                 base: Optional[Snapshot] = None) -> Dict[int, Dict]:
    """Fold journal records (over an optional snapshot base) into one
    request table: rid -> {prompt, max_new, arrival_s, tokens, reason}.

    Application is idempotent: token records assign at their absolute
    start index, submit records only create missing entries, finish
    records overwrite the reason. Replaying the whole journal over any
    snapshot therefore converges to the same table.

    A token record whose start index lies beyond the tokens accumulated
    so far is **mid-file corruption**, not a torn tail: fsync batching
    flushes earlier tokens before later ones, so an intact journal can
    never produce a gap. The rid keeps its consistent prefix, every
    later token record for it is ignored (applying past the gap would
    fabricate an inconsistent stream), and the entry is flagged with
    ``token_gap=True`` so recovery can report it instead of silently
    replaying a short prefix as durable truth.
    """
    table: Dict[int, Dict] = {}
    if base is not None:
        for rid, r in base.requests.items():
            table[rid] = {"prompt": np.asarray(r["prompt"]),
                          "tokens": list(r["tokens"]),
                          "max_new": r["max_new"],
                          "reason": r.get("reason"),
                          "arrival_s": r.get("arrival_s", 0.0),
                          "spec": r.get("spec", False)}
    for rec in records:
        t = rec["t"]
        if t == "submit":
            rid = rec["rid"]
            if rid not in table:
                table[rid] = {"prompt": np.asarray(rec["prompt"], np.int32),
                              "tokens": [],
                              "max_new": rec["max_new"],
                              "reason": None,
                              "arrival_s": rec.get("arrival_s", 0.0),
                              "spec": rec.get("spec", False)}
        elif t == "token":
            r = table.get(rec["rid"])
            if r is None:          # token for an unjournaled submit: skip
                logger.warning("journal: token record for unknown rid %s",
                               rec["rid"])
                continue
            if r.get("token_gap"):     # rid poisoned by an earlier gap
                continue
            i, toks = rec["i"], rec["tok"]
            if len(r["tokens"]) < i:   # mid-file corruption (see above)
                logger.error(
                    "journal: token gap for rid %s at index %d (have %d "
                    "tokens) — mid-file corruption; keeping the consistent "
                    "prefix and ignoring this rid's later token records",
                    rec["rid"], i, len(r["tokens"]))
                r["token_gap"] = True
                continue
            r["tokens"][i:i + len(toks)] = toks
        elif t == "finish":
            r = table.get(rec["rid"])
            if r is not None:
                r["reason"] = rec["reason"]
        elif t == "cancel":
            r = table.get(rec["rid"])
            if r is not None and r["reason"] is None:
                r["cancel_requested"] = True
        # "admit" / "snapshot" / "drain" records carry no table state
    return table


def last_snapshot_record(records: List[Dict]) -> Optional[Dict]:
    for rec in reversed(records):
        if rec["t"] == "snapshot":
            return rec
    return None
