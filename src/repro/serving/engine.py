"""Batched serving engine: prefill -> decode loop, optional speculative
decoding (draft model + ragged per-request acceptance), XShare routing
policies applied per decode/verify step, OTPS accounting.

All requests advance in lockstep steps (static shapes for jit); ragged
speculative acceptance is handled with per-row cache cur_len vectors, so
each request's cache stays exact while the batch stays rectangular —
the same structure vLLM-style engines use for batched verification.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig, XSharePolicy
from repro.models import decode_step, prefill
from repro.models.moe import OFF
from repro.serving.sampler import greedy, sample
from repro.serving.spec_decode import greedy_accept


@dataclass
class GenStats:
    prompt_len: int = 0
    steps: int = 0
    new_tokens: int = 0
    wall_s: float = 0.0
    accepted_hist: List[int] = field(default_factory=list)
    layer_aux: List[Dict] = field(default_factory=list)

    @property
    def otps(self) -> float:
        return self.new_tokens / max(self.wall_s, 1e-9)

    @property
    def mean_accepted(self) -> float:
        return float(np.mean(self.accepted_hist)) if self.accepted_hist \
            else 0.0

    def mean_aux(self, key: str) -> float:
        vals = [float(np.mean(a[key])) for a in self.layer_aux if key in a]
        return float(np.mean(vals)) if vals else float("nan")


class Engine:
    """Serving engine for one model (+ optional draft model)."""

    def __init__(self, cfg: ArchConfig, params, *,
                 policy: XSharePolicy = OFF,
                 cache_len: int = 512,
                 force_window: Optional[int] = None,
                 capacity_factor: float = 8.0,
                 draft: Optional[Tuple[ArchConfig, dict]] = None,
                 spec_len: int = 0,
                 temperature: float = 0.0,
                 seed: int = 0):
        self.cfg, self.params = cfg, params
        self.policy = policy
        self.spec_len = spec_len
        self.temperature = temperature
        self.cache_len = cache_len
        self._key = jax.random.PRNGKey(seed)
        if spec_len and cfg.family == "audio":
            raise NotImplementedError("spec decode for codebook streams")
        if spec_len and not draft:
            raise ValueError("spec_len > 0 requires a draft model")
        self.draft = draft

        cf = capacity_factor
        self._prefill = jax.jit(lambda p, t: prefill(
            cfg, p, t, cache_len=cache_len, policy=OFF,
            force_window=force_window, capacity_factor=cf))
        self._decode = jax.jit(lambda p, t, c: decode_step(
            cfg, p, t, c, policy=policy, force_window=force_window,
            capacity_factor=cf))
        spec_policy = policy if policy.mode in ("off", "spec") else OFF
        self._verify = jax.jit(lambda p, t, c: decode_step(
            cfg, p, t, c, policy=spec_policy,
            spec_shape=(t.shape[0], t.shape[1]),
            force_window=force_window, capacity_factor=cf))
        if draft:
            dcfg, _ = draft
            self._dprefill = jax.jit(lambda p, t: prefill(
                dcfg, p, t, cache_len=cache_len, capacity_factor=cf))
            self._ddecode = jax.jit(lambda p, t, c: decode_step(
                dcfg, p, t, c, capacity_factor=cf))

    # ------------------------------------------------------------------ --

    def _pick(self, logits: jnp.ndarray) -> jnp.ndarray:
        if self.temperature == 0.0:
            return greedy(logits)
        self._key, k = jax.random.split(self._key)
        return sample(logits, k, temperature=self.temperature)

    def generate(self, prompts: np.ndarray, max_new_tokens: int,
                 *, prefix_embeds=None) -> Tuple[np.ndarray, GenStats]:
        """prompts: (B, S) int32 ((B,S,K) audio). Returns
        (tokens (B, <=max_new_tokens[, K]), stats). Greedy unless
        temperature > 0."""
        if self.spec_len:
            return self._generate_spec(prompts, max_new_tokens)
        return self._generate_plain(prompts, max_new_tokens,
                                    prefix_embeds=prefix_embeds)

    # ------------------------------------------------------------ plain --

    def _generate_plain(self, prompts, max_new_tokens, *, prefix_embeds):
        stats = GenStats(prompt_len=prompts.shape[1])
        t0 = time.perf_counter()
        if prefix_embeds is not None:
            lg, cache, _ = jax.jit(
                lambda p, t, pe: prefill(
                    self.cfg, p, t, cache_len=self.cache_len,
                    prefix_embeds=pe))(self.params, prompts, prefix_embeds)
        else:
            lg, cache, _ = self._prefill(self.params, prompts)
        tok = self._pick(lg)                                # (B,) or (B,K)
        outs = [np.asarray(tok)]
        for _ in range(max_new_tokens - 1):
            t_in = tok[:, None]                             # (B,1[,K])
            lg, cache, aux = self._decode(self.params, t_in, cache)
            tok = self._pick(lg[:, -1])
            outs.append(np.asarray(tok))
            stats.steps += 1
            if aux:
                stats.layer_aux.append(
                    {k: np.asarray(v) for k, v in aux.items()})
        toks = np.stack(outs, axis=1)
        stats.new_tokens = int(np.prod(toks.shape))  # audio: K per frame
        stats.wall_s = time.perf_counter() - t0
        return toks, stats

    # ------------------------------------------------------------- spec --

    def _generate_spec(self, prompts, max_new_tokens):
        dcfg, dparams = self.draft
        B, S = prompts.shape
        Ls = self.spec_len
        stats = GenStats(prompt_len=S)
        t0 = time.perf_counter()

        lg, cache, _ = self._prefill(self.params, prompts)
        _, dcache, _ = self._dprefill(dparams, prompts)
        cur = jnp.full((B,), S, jnp.int32)
        cache["cur_len"] = cur
        dcache["cur_len"] = cur
        x0 = greedy(lg)                                     # (B,)
        out_tok: List[List[int]] = [[int(x0[b])] for b in range(B)]

        while min(len(o) for o in out_tok) < max_new_tokens:
            # -- draft Ls tokens (one extra step writes the last kv) -------
            drafts = []
            dtok = x0
            for i in range(Ls + 1):
                dlg, dcache, _ = self._ddecode(dparams, dtok[:, None],
                                               dcache)
                dtok = greedy(dlg[:, -1])
                if i < Ls:
                    drafts.append(dtok)
            drafts = jnp.stack(drafts, axis=1)              # (B, Ls)

            # -- verify on the target (the paper's amplified batch) --------
            verify_in = jnp.concatenate([x0[:, None], drafts], axis=1)
            old_cur = cache["cur_len"]
            vlg, cache, aux = self._verify(self.params, verify_in, cache)
            res = greedy_accept(vlg, drafts)

            # -- ragged rollback -------------------------------------------
            new_cur = old_cur + res.num_new
            cache["cur_len"] = new_cur
            dcache["cur_len"] = new_cur
            x0 = jnp.take_along_axis(res.new_tokens,
                                     res.accepted[:, None], axis=1)[:, 0]
            nt = np.asarray(res.new_tokens)
            nn = np.asarray(res.num_new)
            for b in range(B):
                out_tok[b].extend(int(t) for t in nt[b, :nn[b]])
            stats.steps += 1
            stats.accepted_hist.append(float(np.mean(np.asarray(
                res.accepted))))
            if aux:
                stats.layer_aux.append(
                    {k: np.asarray(v) for k, v in aux.items()})

        stats.new_tokens = sum(min(len(o), max_new_tokens)
                               for o in out_tok)
        stats.wall_s = time.perf_counter() - t0
        toks = np.full((B, max_new_tokens), -1, np.int32)
        for b in range(B):
            row = out_tok[b][:max_new_tokens]
            toks[b, :len(row)] = row
        return toks, stats
