"""Serving engine facade over the continuous-batching subsystem.

Three layers (docs in each module):

  serving/scheduler.py  — request queue, slot lifecycle, XShare-aware
                          admission (batch composition by expert affinity)
  serving/step.py       — fused on-device decode: sampling inside jit,
                          lax.scan over N tokens per dispatch, per-slot
                          active masks
  serving/engine.py     — this facade: preserves the original
                          ``generate()`` API (plain + speculative paths,
                          GenStats / OTPS accounting)

Plain generation routes through the scheduler (all requests arrive at
t=0) and is token-exact vs. the retained lockstep loop under greedy
sampling. Speculative decoding keeps the host-side draft/verify loop
with ragged per-request acceptance; per-row cache cur_len vectors are
now the universal cache representation (models/model.py), so the spec
path no longer patches them in by hand.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig, XSharePolicy
from repro.models import decode_step, prefill
from repro.models.model import effective_window
from repro.models.moe import OFF
from repro.serving.errors import validate_request
from repro.serving.sampler import greedy, sample_step
from repro.serving.scheduler import Scheduler
from repro.serving.spec_decode import greedy_accept, rollback_cur_len
from repro.serving.spec_scheduler import SpecConfig, SpecScheduler
from repro.serving.step import build_spec_fns, build_step_fns


@dataclass
class GenStats:
    prompt_len: int = 0
    steps: int = 0
    new_tokens: int = 0
    wall_s: float = 0.0
    accepted_hist: List[int] = field(default_factory=list)
    layer_aux: List[Dict] = field(default_factory=list)
    # speculative-decoding counters (scheduler-integrated path)
    drafted: int = 0              # draft tokens proposed
    accepted: int = 0             # draft tokens the target accepted
    spec_budget_exhausted: int = 0  # requests that ran out of budget

    @property
    def otps(self) -> float:
        return self.new_tokens / max(self.wall_s, 1e-9)

    @property
    def mean_accepted(self) -> float:
        return float(np.mean(self.accepted_hist)) if self.accepted_hist \
            else 0.0

    @property
    def acceptance_rate(self) -> float:
        """Accepted / drafted (0.0 when nothing was drafted)."""
        return self.accepted / self.drafted if self.drafted else 0.0

    def mean_aux(self, key: str) -> float:
        vals = [float(np.mean(a[key])) for a in self.layer_aux if key in a]
        return float(np.mean(vals)) if vals else float("nan")


class Engine:
    """Serving engine for one model (+ optional draft model)."""

    def __init__(self, cfg: ArchConfig, params, *,
                 policy: XSharePolicy = OFF,
                 cache_len: int = 512,
                 force_window: Optional[int] = None,
                 capacity_factor: float = 8.0,
                 draft: Optional[Tuple[ArchConfig, dict]] = None,
                 spec_len: int = 0,
                 spec_rounds: int = 4,
                 spec_budget: Optional[int] = None,
                 spec_adapt: bool = True,
                 temperature: float = 0.0,
                 decode_chunk: int = 8,
                 dispatch: str = "auto",
                 seed: int = 0):
        self.cfg, self.params = cfg, params
        self.policy = policy
        self.spec_len = spec_len
        self.spec_rounds = spec_rounds
        self.spec_budget = spec_budget
        self.spec_adapt = spec_adapt
        self.temperature = temperature
        self.cache_len = cache_len
        self.force_window = force_window
        self.capacity_factor = capacity_factor
        self.decode_chunk = decode_chunk
        self.dispatch = dispatch
        self._key = jax.random.PRNGKey(seed)
        if spec_len and cfg.family == "audio":
            raise NotImplementedError("spec decode for codebook streams")
        if spec_len and not draft:
            raise ValueError("spec_len > 0 requires a draft model")
        self.draft = draft

        cf = capacity_factor
        dsp = dispatch
        self._prefill = jax.jit(lambda p, t: prefill(
            cfg, p, t, cache_len=cache_len, policy=OFF,
            force_window=force_window, capacity_factor=cf, dispatch=dsp))
        # hoisted once (the seed rebuilt this closure — and recompiled —
        # on every generate(prefix_embeds=...) call)
        self._prefill_pe = jax.jit(lambda p, t, pe: prefill(
            cfg, p, t, cache_len=cache_len, policy=OFF, prefix_embeds=pe,
            force_window=force_window, capacity_factor=cf, dispatch=dsp))
        self._decode = jax.jit(lambda p, t, c: decode_step(
            cfg, p, t, c, policy=policy, force_window=force_window,
            capacity_factor=cf, dispatch=dsp))
        spec_policy = policy if policy.mode in ("off", "spec") else OFF
        self._verify = jax.jit(lambda p, t, c: decode_step(
            cfg, p, t, c, policy=spec_policy,
            spec_shape=(t.shape[0], t.shape[1]),
            force_window=force_window, capacity_factor=cf, dispatch=dsp))
        if draft:
            dcfg, _ = draft
            self._dprefill = jax.jit(lambda p, t: prefill(
                dcfg, p, t, cache_len=cache_len, capacity_factor=cf))
            self._ddecode = jax.jit(lambda p, t, c: decode_step(
                dcfg, p, t, c, capacity_factor=cf))
        # speculative scheduler bundle (lazy compile under jit): the
        # fused draft-then-verify scan + draft prefill, shared by every
        # SpecScheduler / FrontDoor this engine creates
        self._spec_fns = None
        self._spec_fused_levels = {}
        if draft and spec_len:
            self._spec_fns = build_spec_fns(
                cfg, draft[0], policy=spec_policy, spec_len=spec_len,
                num_rounds=spec_rounds, cache_len=cache_len,
                force_window=force_window, capacity_factor=cf,
                dispatch=dsp)
        # shared compiled bundle for the continuous path (jit retraces
        # per batch size, so one bundle serves every generate() call)
        self._fns = build_step_fns(
            cfg, policy=policy, cache_len=cache_len,
            decode_chunk=decode_chunk, temperature=temperature,
            force_window=force_window, capacity_factor=cf, dispatch=dsp)
        self._fns_by_chunk = {}   # make_scheduler decode_chunk overrides
        self._fused_levels = {}   # degradation-level fused fns, per chunk

    # ------------------------------------------------------------------ --

    def _pick(self, logits: jnp.ndarray) -> jnp.ndarray:
        self._key, k = jax.random.split(self._key)
        return sample_step(logits, k, temperature=self.temperature)

    def make_scheduler(self, *, num_slots: int,
                       admission: str = "fcfs",
                       decode_chunk: Optional[int] = None,
                       spec_cfg: Optional[SpecConfig] = None,
                       **robustness) -> Scheduler:
        """A Scheduler wired to this engine's compiled functions —
        the entry point for open-ended (arrival-process) serving.

        An engine with a draft model and spec_len > 0 gets a
        SpecScheduler (speculative and plain requests share one running
        batch; submit(spec=False) opts a request out); spec_cfg
        overrides the engine-derived SpecConfig. Other engines get the
        plain Scheduler.

        decode_chunk overrides the engine default (shorter chunks trade
        throughput for admission latency under live traffic); a new
        compiled bundle is built when it differs. Extra keyword args
        (max_queue, overload, watchdog_s, degrade, invariants, faults,
        on_round, ...) pass through to the Scheduler's robustness
        layer."""
        self._key, k = jax.random.split(self._key)
        fns = self._fns
        if decode_chunk is not None and decode_chunk != self.decode_chunk:
            if decode_chunk not in self._fns_by_chunk:
                self._fns_by_chunk[decode_chunk] = build_step_fns(
                    self.cfg, policy=self.policy, cache_len=self.cache_len,
                    decode_chunk=decode_chunk,
                    temperature=self.temperature,
                    force_window=self.force_window,
                    capacity_factor=self.capacity_factor,
                    dispatch=self.dispatch)
            fns = self._fns_by_chunk[decode_chunk]
        common = dict(
            num_slots=num_slots, cache_len=self.cache_len,
            policy=self.policy, admission=admission,
            decode_chunk=decode_chunk or self.decode_chunk,
            temperature=self.temperature, force_window=self.force_window,
            capacity_factor=self.capacity_factor, dispatch=self.dispatch,
            fns=fns, fused_cache=self._fused_levels.setdefault(
                decode_chunk or self.decode_chunk, {}), **robustness)
        if self._spec_fns is not None:
            sc = spec_cfg or SpecConfig(
                spec_len=self.spec_len, num_rounds=self.spec_rounds,
                budget=self.spec_budget, adapt=self.spec_adapt)
            spec_fns = self._spec_fns
            if (sc.spec_len != self._spec_fns.spec_len
                    or sc.num_rounds != self._spec_fns.num_rounds):
                spec_fns = None        # SpecScheduler builds its own
            spec_policy = self.policy \
                if self.policy.mode in ("off", "spec") else OFF
            common["policy"] = spec_policy
            sched = SpecScheduler(
                self.cfg, self.params, draft=self.draft, spec_cfg=sc,
                spec_fns=spec_fns,
                spec_fused_cache=self._spec_fused_levels, **common)
        else:
            sched = Scheduler(self.cfg, self.params, **common)
        sched._key = k
        return sched

    def make_frontdoor(self, *, num_slots: int, **door_kw):
        """A started crash-tolerant streaming FrontDoor over this
        engine (serving/frontdoor.py): per-request token streams,
        mid-stream cancel, graceful drain, and — with journal_path /
        snapshot_path set — durable recovery via recover()."""
        from repro.serving.frontdoor import FrontDoor
        return FrontDoor(self, num_slots=num_slots, **door_kw).start()

    def generate(self, prompts: np.ndarray, max_new_tokens: int,
                 *, prefix_embeds=None,
                 lockstep: bool = False) -> Tuple[np.ndarray, GenStats]:
        """prompts: (B, S) int32 ((B,S,K) audio). Returns
        (tokens (B, <=max_new_tokens[, K]), stats). Greedy unless
        temperature > 0.

        lockstep=True forces the legacy per-token host loop (reference
        implementation for equivalence tests / benchmarks); the default
        path serves the batch through the continuous scheduler with all
        requests arriving at t=0, which is token-exact with lockstep
        under greedy sampling. With a draft model (spec_len > 0) the
        default path is the scheduler-integrated speculative subsystem
        (serving/spec_scheduler.py) and lockstep=True is the retained
        host-side draft/verify reference loop."""
        prompts = np.asarray(prompts)
        # front-door validation (serving/errors.py taxonomy): a prompt
        # that can't fit the cache must fail HERE with InvalidRequest,
        # not as a cache-splice shape error deep in prefill
        validate_request(
            int(prompts.shape[1]), max_new_tokens,
            cache_len=self.cache_len,
            window=effective_window(self.cfg,
                                    force_window=self.force_window))
        if self.spec_len:
            if lockstep or self.temperature != 0.0:
                return self._generate_spec(prompts, max_new_tokens)
            return self._generate_continuous(prompts, max_new_tokens)
        if lockstep or prefix_embeds is not None:
            return self._generate_lockstep(prompts, max_new_tokens,
                                           prefix_embeds=prefix_embeds)
        return self._generate_continuous(prompts, max_new_tokens)

    # ------------------------------------------------------- continuous --

    def _generate_continuous(self, prompts, max_new_tokens):
        B = prompts.shape[0]
        stats = GenStats(prompt_len=prompts.shape[1])
        t0 = time.perf_counter()
        sched = self.make_scheduler(num_slots=B, admission="fcfs")
        for b in range(B):
            sched.submit(prompts[b], max_new_tokens)
        states = sched.run()
        toks = np.stack([np.stack(st.tokens[:max_new_tokens])
                         for st in states])
        # per-request accounting is already trimmed to each request's
        # horizon; batch-level sched.total_steps/step_aux include chunk
        # overshoot past it, which the lockstep reference never runs
        stats.steps = max(len(st.tokens) for st in states) - 1
        stats.layer_aux = max((st.layer_aux for st in states), key=len)
        stats.new_tokens = int(np.prod(toks.shape))  # audio: K per frame
        if isinstance(sched, SpecScheduler):
            stats.steps = sched.total_steps       # draft-verify rounds
            stats.accepted_hist = list(sched.round_accept_hist)
            stats.drafted = sched.total_drafted
            stats.accepted = sched.total_accepted
            stats.spec_budget_exhausted = sched.budget_exhausted_events
        stats.wall_s = time.perf_counter() - t0
        return toks, stats

    # ------------------------------------------- lockstep (reference) ----

    def _generate_lockstep(self, prompts, max_new_tokens, *,
                           prefix_embeds=None):
        """Seed-style per-token host loop: one decode dispatch and one
        device->host sync per token. Kept as the reference for the
        continuous engine's exactness tests and as the prefix-embeds
        (vlm/audio frontend) path."""
        stats = GenStats(prompt_len=prompts.shape[1])
        t0 = time.perf_counter()
        if prefix_embeds is not None:
            lg, cache, _ = self._prefill_pe(self.params, prompts,
                                            prefix_embeds)
        else:
            lg, cache, _ = self._prefill(self.params, prompts)
        tok = self._pick(lg)                                # (B,) or (B,K)
        outs = [np.asarray(tok)]
        for _ in range(max_new_tokens - 1):
            t_in = tok[:, None]                             # (B,1[,K])
            lg, cache, aux = self._decode(self.params, t_in, cache)
            tok = self._pick(lg[:, -1])
            outs.append(np.asarray(tok))
            stats.steps += 1
            if aux:
                stats.layer_aux.append(
                    {k: np.asarray(v) for k, v in aux.items()})
        toks = np.stack(outs, axis=1)
        stats.new_tokens = int(np.prod(toks.shape))  # audio: K per frame
        stats.wall_s = time.perf_counter() - t0
        return toks, stats

    # ------------------------------------------------------------- spec --

    def _generate_spec(self, prompts, max_new_tokens):
        dcfg, dparams = self.draft
        B, S = prompts.shape
        Ls = self.spec_len
        stats = GenStats(prompt_len=S)
        t0 = time.perf_counter()

        lg, cache, _ = self._prefill(self.params, prompts)
        _, dcache, _ = self._dprefill(dparams, prompts)
        x0 = greedy(lg)                                     # (B,)
        out_tok: List[List[int]] = [[int(x0[b])] for b in range(B)]

        while min(len(o) for o in out_tok) < max_new_tokens:
            # -- draft Ls tokens (one extra step writes the last kv) -------
            drafts = []
            dtok = x0
            for i in range(Ls + 1):
                dlg, dcache, _ = self._ddecode(dparams, dtok[:, None],
                                               dcache)
                dtok = greedy(dlg[:, -1])
                if i < Ls:
                    drafts.append(dtok)
            drafts = jnp.stack(drafts, axis=1)              # (B, Ls)

            # -- verify on the target (the paper's amplified batch) --------
            verify_in = jnp.concatenate([x0[:, None], drafts], axis=1)
            old_cur = cache["cur_len"]
            vlg, cache, aux = self._verify(self.params, verify_in, cache)
            res = greedy_accept(vlg, drafts)

            # -- ragged rollback -------------------------------------------
            new_cur = rollback_cur_len(old_cur, res)
            cache["cur_len"] = new_cur
            dcache["cur_len"] = new_cur
            x0 = jnp.take_along_axis(res.new_tokens,
                                     res.accepted[:, None], axis=1)[:, 0]
            nt = np.asarray(res.new_tokens)
            nn = np.asarray(res.num_new)
            for b in range(B):
                out_tok[b].extend(int(t) for t in nt[b, :nn[b]])
            stats.steps += 1
            stats.accepted_hist.append(float(np.mean(np.asarray(
                res.accepted))))
            stats.drafted += Ls * B
            stats.accepted += int(np.asarray(res.accepted).sum())
            if aux:
                stats.layer_aux.append(
                    {k: np.asarray(v) for k, v in aux.items()})

        stats.new_tokens = sum(min(len(o), max_new_tokens)
                               for o in out_tok)
        stats.wall_s = time.perf_counter() - t0
        toks = np.full((B, max_new_tokens), -1, np.int32)
        for b in range(B):
            row = out_tok[b][:max_new_tokens]
            toks[b, :len(row)] = row
        return toks, stats
