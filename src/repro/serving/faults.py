"""Deterministic, seeded fault-injection harness for the serving stack.

Four fault classes, mirroring the failure modes a production MoE
deployment actually sees (host hiccups, device numerics, cache-surgery
races, stalled dispatch):

  slow_prefill  — host-side delay before the prefill of request `rid`
                  (slow tokenizer / weight paging / noisy neighbor).
  nan_logits    — non-finite logits on slot `slot` at global decode step
                  `step`, injected as a traced operand *inside* the
                  fused scan (serving/step.py) so the quarantine path is
                  exercised in the exact compiled function production
                  runs.
  insert_fail   — the cache splice (insert_request) for request `rid`
                  raises a TransientFault for its first `times`
                  attempts; the scheduler's retry/backoff either
                  recovers (times <= max_retries) or sheds the request.
  stall_decode  — host-side delay before fused decode round `step`
                  (device preemption / collective stall); trips the
                  step-time watchdog.

Faults are specified explicitly (fully deterministic) or drawn from a
seeded RNG (`sample_campaign`) — either way a campaign replays
bit-identically, which is what lets tests assert that co-batched
requests are token-exact against a fault-free run.

Every delivered fault is appended to ``injector.log`` as
``(kind, target, detail)`` so campaigns can assert delivery.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import List, Optional, Tuple

import numpy as np

from repro.serving.errors import TransientFault

KINDS = ("slow_prefill", "nan_logits", "insert_fail", "stall_decode")


class InjectedFault(TransientFault):
    """A fault raised by the injector (retryable by the watchdog)."""
    code = "injected_fault"


@dataclass
class Fault:
    """One planned fault. Targeting fields by kind:

    slow_prefill: rid, delay_s
    nan_logits:   slot, step (global decode-step index)
    insert_fail:  rid, times (attempts that fail)
    stall_decode: step (fused round index), delay_s
    """
    kind: str
    rid: int = -1
    slot: int = -1
    step: int = -1
    delay_s: float = 0.0
    times: int = 1

    def __post_init__(self):
        if self.kind not in KINDS:
            raise ValueError(f"unknown fault kind {self.kind!r}")


@dataclass
class FaultInjector:
    """Delivers a planned fault campaign into the scheduler's hooks."""
    faults: List[Fault] = field(default_factory=list)
    log: List[Tuple[str, int, float]] = field(default_factory=list)
    _insert_attempts: dict = field(default_factory=dict)

    # ----------------------------------------------------------- hooks ----

    def before_prefill(self, rids: List[int]) -> None:
        """Called with the rids of one admission group, pre-prefill."""
        delay = sum(f.delay_s for f in self.faults
                    if f.kind == "slow_prefill" and f.rid in rids)
        if delay:
            self.log.append(("slow_prefill", rids[0], delay))
            time.sleep(delay)

    def before_insert(self, rid: int) -> None:
        """Called before each insert_request attempt; raises to fail it."""
        for f in self.faults:
            if f.kind == "insert_fail" and f.rid == rid:
                n = self._insert_attempts.get(rid, 0)
                self._insert_attempts[rid] = n + 1
                if n < f.times:
                    self.log.append(("insert_fail", rid, float(n)))
                    raise InjectedFault(
                        f"injected insert failure rid={rid} attempt={n}")

    def before_round(self, round_idx: int) -> None:
        """Called before fused decode round `round_idx`."""
        for f in self.faults:
            if f.kind == "stall_decode" and f.step == round_idx:
                self.log.append(("stall_decode", round_idx, f.delay_s))
                time.sleep(f.delay_s)

    def nan_fault(self, step_lo: int, step_hi: int) -> Tuple[int, int]:
        """(slot, step-in-chunk) of the first nan_logits fault whose
        global step falls in [step_lo, step_hi), else (-1, -1). The pair
        is fed to the fused scan as a traced operand, so asking costs no
        recompile."""
        for f in self.faults:
            if f.kind == "nan_logits" and step_lo <= f.step < step_hi:
                self.log.append(("nan_logits", f.slot, float(f.step)))
                return f.slot, f.step - step_lo
        return -1, -1


def sample_campaign(seed: int, *, num_requests: int, num_slots: int,
                    horizon_steps: int,
                    p_slow: float = 0.25, p_nan: float = 0.5,
                    p_insert: float = 0.25, p_stall: float = 0.5,
                    delay_s: float = 0.02,
                    insert_times: Optional[int] = None) -> FaultInjector:
    """A reproducible mixed campaign drawn from one seeded RNG.

    Each fault class fires independently with its probability; targets
    (rid / slot / step) are drawn uniformly over the campaign extent.
    The same seed always yields the same campaign.
    """
    rng = np.random.default_rng(seed)
    faults: List[Fault] = []
    if rng.random() < p_slow:
        faults.append(Fault("slow_prefill",
                            rid=int(rng.integers(num_requests)),
                            delay_s=delay_s))
    if rng.random() < p_nan:
        faults.append(Fault("nan_logits",
                            slot=int(rng.integers(num_slots)),
                            step=int(rng.integers(1, horizon_steps))))
    if rng.random() < p_insert:
        faults.append(Fault("insert_fail",
                            rid=int(rng.integers(num_requests)),
                            times=insert_times if insert_times is not None
                            else int(rng.integers(1, 4))))
    if rng.random() < p_stall:
        faults.append(Fault("stall_decode",
                            step=int(rng.integers(1, max(
                                2, horizon_steps // 4))),
                            delay_s=delay_s))
    return FaultInjector(faults=faults)
