"""Deterministic, seeded fault-injection harness for the serving stack.

Step-level fault classes, mirroring the failure modes a production MoE
deployment actually sees (host hiccups, device numerics, cache-surgery
races, stalled dispatch):

  slow_prefill  — host-side delay before the prefill of request `rid`
                  (slow tokenizer / weight paging / noisy neighbor).
  nan_logits    — non-finite logits on slot `slot` at global decode step
                  `step`, injected as a traced operand *inside* the
                  fused scan (serving/step.py) so the quarantine path is
                  exercised in the exact compiled function production
                  runs.
  insert_fail   — the cache splice (insert_request) for request `rid`
                  raises a TransientFault for its first `times`
                  attempts; the scheduler's retry/backoff either
                  recovers (times <= max_retries) or sheds the request.
  stall_decode  — host-side delay before fused decode round `step`
                  (device preemption / collective stall); trips the
                  step-time watchdog.

Process-level fault classes (the crash-tolerance layer's adversaries —
serving/frontdoor.py + serving/journal.py):

  crash_before_snapshot — the process dies just before snapshot number
                  `step` is written (SimulatedCrash raised from the
                  front door's before_snapshot hook): recovery must
                  come from an OLDER snapshot + journal tail, or from
                  the journal alone.
  crash_mid_round — the process dies entering fused decode round
                  `step`: every in-flight request's device state is
                  lost; only the journal + last snapshot survive.
  journal_torn_write — the crash tears the journal's final record:
                  `nbytes` bytes of the first unflushed record land on
                  disk (JournalWriter.abandon). The journal reader must
                  log-and-skip the torn tail, not crash.

A SimulatedCrash deliberately subclasses ServingError but NOT
TransientFault: the watchdog must never retry it — it propagates out of
the serve loop like the process death it stands in for.

Faults are specified explicitly (fully deterministic) or drawn from a
seeded RNG (`sample_campaign`) — either way a campaign replays
bit-identically, which is what lets tests assert that co-batched
requests are token-exact against a fault-free run and that two runs of
the same campaign seed produce identical survival/reason counts.

Every delivered fault is appended to ``injector.log`` as
``(kind, target, detail)`` so campaigns can assert delivery.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import List, Optional, Set, Tuple

import numpy as np

from repro.serving.errors import ServingError, TransientFault

STEP_KINDS = ("slow_prefill", "nan_logits", "insert_fail", "stall_decode")
PROCESS_KINDS = ("crash_before_snapshot", "crash_mid_round",
                 "journal_torn_write")
KINDS = STEP_KINDS + PROCESS_KINDS


class InjectedFault(TransientFault):
    """A fault raised by the injector (retryable by the watchdog)."""
    code = "injected_fault"


class SimulatedCrash(ServingError):
    """Process death, delivered as an exception: NOT retryable (not a
    TransientFault) — it unwinds the serve loop the way a SIGKILL
    unwinds the process. The front door's crash path (journal abandon,
    stream abort) is exercised by catching exactly this."""
    code = "simulated_crash"


@dataclass
class Fault:
    """One planned fault. Targeting fields by kind:

    slow_prefill: rid, delay_s
    nan_logits:   slot, step (global decode-step index)
    insert_fail:  rid, times (attempts that fail)
    stall_decode: step (fused round index), delay_s
    crash_before_snapshot: step (snapshot index)
    crash_mid_round:       step (fused round index)
    journal_torn_write:    nbytes (bytes of the torn record left on disk)
    """
    kind: str
    rid: int = -1
    slot: int = -1
    step: int = -1
    delay_s: float = 0.0
    times: int = 1
    nbytes: int = 0

    def __post_init__(self):
        if self.kind not in KINDS:
            raise ValueError(f"unknown fault kind {self.kind!r}")


@dataclass
class FaultInjector:
    """Delivers a planned fault campaign into the scheduler's (and
    front door's) hooks. Crash faults fire at most once per injector,
    so a recovered incarnation reusing the plan does not re-die."""
    faults: List[Fault] = field(default_factory=list)
    log: List[Tuple[str, int, float]] = field(default_factory=list)
    _insert_attempts: dict = field(default_factory=dict)
    _crashed: Set[str] = field(default_factory=set)

    # ----------------------------------------------------------- hooks ----

    def before_prefill(self, rids: List[int]) -> None:
        """Called with the rids of one admission group, pre-prefill."""
        delay = sum(f.delay_s for f in self.faults
                    if f.kind == "slow_prefill" and f.rid in rids)
        if delay:
            self.log.append(("slow_prefill", rids[0], delay))
            time.sleep(delay)

    def before_insert(self, rid: int) -> None:
        """Called before each insert_request attempt; raises to fail it."""
        for f in self.faults:
            if f.kind == "insert_fail" and f.rid == rid:
                n = self._insert_attempts.get(rid, 0)
                self._insert_attempts[rid] = n + 1
                if n < f.times:
                    self.log.append(("insert_fail", rid, float(n)))
                    raise InjectedFault(
                        f"injected insert failure rid={rid} attempt={n}")

    def before_round(self, round_idx: int) -> None:
        """Called before fused decode round `round_idx`. Raises
        SimulatedCrash when a crash_mid_round fault targets it."""
        for f in self.faults:
            if f.kind == "stall_decode" and f.step == round_idx:
                self.log.append(("stall_decode", round_idx, f.delay_s))
                time.sleep(f.delay_s)
        for f in self.faults:
            if f.kind == "crash_mid_round" and f.step == round_idx \
                    and "crash_mid_round" not in self._crashed:
                self._crashed.add("crash_mid_round")
                self.log.append(("crash_mid_round", round_idx, 0.0))
                raise SimulatedCrash(
                    f"injected process crash entering round {round_idx}")

    def before_snapshot(self, snap_idx: int) -> None:
        """Called by the front door before writing snapshot `snap_idx`."""
        for f in self.faults:
            if f.kind == "crash_before_snapshot" and f.step == snap_idx \
                    and "crash_before_snapshot" not in self._crashed:
                self._crashed.add("crash_before_snapshot")
                self.log.append(("crash_before_snapshot", snap_idx, 0.0))
                raise SimulatedCrash(
                    f"injected process crash before snapshot {snap_idx}")

    def nan_fault(self, step_lo: int, step_hi: int) -> Tuple[int, int]:
        """(slot, step-in-chunk) of the first nan_logits fault whose
        global step falls in [step_lo, step_hi), else (-1, -1). The pair
        is fed to the fused scan as a traced operand, so asking costs no
        recompile."""
        for f in self.faults:
            if f.kind == "nan_logits" and step_lo <= f.step < step_hi:
                self.log.append(("nan_logits", f.slot, float(f.step)))
                return f.slot, f.step - step_lo
        return -1, -1

    def torn_tail_bytes(self) -> int:
        """Bytes of torn journal prefix a crash leaves behind (0 = the
        buffered tail vanishes cleanly). Consulted by the front door's
        crash path when abandoning the journal."""
        for f in self.faults:
            if f.kind == "journal_torn_write":
                self.log.append(("journal_torn_write", -1,
                                 float(f.nbytes)))
                return f.nbytes
        return 0


def sample_campaign(seed: int, *, num_requests: int, num_slots: int,
                    horizon_steps: int,
                    p_slow: float = 0.25, p_nan: float = 0.5,
                    p_insert: float = 0.25, p_stall: float = 0.5,
                    p_crash: float = 0.0,
                    delay_s: float = 0.02,
                    insert_times: Optional[int] = None) -> FaultInjector:
    """A reproducible mixed campaign drawn from one seeded RNG.

    Each fault class fires independently with its probability; targets
    (rid / slot / step) are drawn uniformly over the campaign extent.
    The same seed always yields the same campaign. Crash faults
    (p_crash; drawn AFTER the step-level classes so pre-existing seeds
    keep their exact plans) pair a crash_mid_round with a 50% chance of
    a journal_torn_write.
    """
    rng = np.random.default_rng(seed)
    faults: List[Fault] = []
    if rng.random() < p_slow:
        faults.append(Fault("slow_prefill",
                            rid=int(rng.integers(num_requests)),
                            delay_s=delay_s))
    if rng.random() < p_nan:
        faults.append(Fault("nan_logits",
                            slot=int(rng.integers(num_slots)),
                            step=int(rng.integers(1, horizon_steps))))
    if rng.random() < p_insert:
        faults.append(Fault("insert_fail",
                            rid=int(rng.integers(num_requests)),
                            times=insert_times if insert_times is not None
                            else int(rng.integers(1, 4))))
    if rng.random() < p_stall:
        faults.append(Fault("stall_decode",
                            step=int(rng.integers(1, max(
                                2, horizon_steps // 4))),
                            delay_s=delay_s))
    if rng.random() < p_crash:
        faults.append(Fault("crash_mid_round",
                            step=int(rng.integers(1, max(
                                2, horizon_steps // 2)))))
        if rng.random() < 0.5:
            faults.append(Fault("journal_torn_write",
                                nbytes=int(rng.integers(1, 16))))
    return FaultInjector(faults=faults)
