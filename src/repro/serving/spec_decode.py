"""Speculative decoding: draft-then-verify with per-request (ragged)
acceptance, greedy matching (EAGLE-style chains verify the same way under
greedy sampling — the draft here is a small autoregressive model).

Verification feeds the target model (1 + L_s) tokens per request —
exactly the batch-shape amplification the paper targets — and routes the
MoE layers with XSharePolicy(mode="spec") so Algorithm 4's hierarchical
per-request selection sees the (b, 1+L_s, E) gate structure.
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp


class SpecResult(NamedTuple):
    accepted: jnp.ndarray     # (B,) number of accepted draft tokens
    new_tokens: jnp.ndarray   # (B, L_s+1) accepted drafts + bonus, padded
    num_new: jnp.ndarray      # (B,) == accepted + 1


def rollback_cur_len(cur_len: jnp.ndarray, res: "SpecResult") -> jnp.ndarray:
    """Ragged cache rollback after verification: each row advances by its
    own accepted count. cur_len is the per-slot (B,) vector that is the
    universal cache representation (models/model.py init_cache) — the
    same one the continuous-batching scheduler gives independent slot
    lifetimes with, so speculative rollback is just another per-row
    update, no special cache shape."""
    return cur_len + res.num_new


def greedy_accept(verify_logits: jnp.ndarray,
                  drafts: jnp.ndarray,
                  limit: Optional[jnp.ndarray] = None) -> SpecResult:
    """verify_logits: (B, 1+L_s, V) target logits for inputs
    [x0, d_1..d_Ls]; drafts: (B, L_s).

    Position i's logits predict the token after [x0, d_1..d_i], so draft
    d_{i+1} is accepted iff it equals argmax(logits[:, i]) and every
    earlier draft was accepted. One bonus token (the target's own pick at
    the first mismatch / after the last draft) is always emitted.

    limit: optional (B,) int32 per-row cap on how many draft positions
    may be considered (a row's effective L_s in a heterogeneous batch:
    adaptive draft lengths, remaining-token clamps, spec budgets, or
    plain rows riding with limit 0). accepted[b] <= limit[b]; with
    limit[b] == 0 the row degenerates to plain greedy decode — accepted
    0, bonus = argmax(logits[:, 0]).
    """
    B, T, _ = verify_logits.shape
    Ls = T - 1
    t_hat = jnp.argmax(verify_logits, axis=-1).astype(jnp.int32)  # (B,1+Ls)
    match = drafts == t_hat[:, :Ls]                               # (B,Ls)
    if limit is not None:
        match = match & (jnp.arange(Ls)[None, :] < limit[:, None])
    accepted = jnp.cumprod(match.astype(jnp.int32), axis=1).sum(axis=1)
    bonus = jnp.take_along_axis(t_hat, accepted[:, None], axis=1)[:, 0]
    # new_tokens[b] = d_1..d_n, bonus, (padding = bonus repeats, masked by
    # num_new downstream)
    pos = jnp.arange(Ls + 1)[None, :]
    from_draft = pos < accepted[:, None]
    padded_drafts = jnp.pad(drafts, ((0, 0), (0, 1)))
    new_tokens = jnp.where(from_draft, padded_drafts, bonus[:, None])
    return SpecResult(accepted=accepted, new_tokens=new_tokens,
                      num_new=accepted + 1)
