"""Crash-tolerant async streaming front door over the Scheduler.

The missing layer between the hardened scheduler (PR 3) and a wire
protocol: requests are submitted from any thread and consumed as
**token streams**; every scheduler guarantee (deadlines, cancellation,
bounded-queue shed, degradation, quarantine) surfaces here through the
serving/errors.py taxonomy; and — the crash-tolerance tentpole — every
admitted request survives a process kill through the durable journal +
snapshot pair (serving/journal.py) and deterministic replay.

Architecture (one serving thread, lock-free scheduler):

    caller threads                 serving thread
    --------------                 --------------------------------
    submit()  ──┐ lock ┌──►  _tick() pump (Scheduler.run keep_alive):
    cancel()  ──┴──────┤       drain inbox -> sched.submit / cancel
                       │       publish new tokens -> TokenStream queues
    TokenStream ◄──────┤       journal token/finish records (fsync-
      iteration        │         batched; lifecycle records sync now)
      .result()        └──     periodic snapshot (atomic tmp+replace)

The scheduler itself stays single-threaded: callers never touch it —
they append to an inbox the pump drains between fused rounds, and read
per-request queues the pump feeds. ``drain()`` closes admissions
(further submits raise ShuttingDown), lets the batch run dry, then
joins the thread and seals the journal.

Crash + recovery contract:

  * A crash (SimulatedCrash from the fault injector, or any real
    exception escaping the serve loop) loses the scheduler's device
    state and the journal's *unflushed* tail — never flushed records.
  * ``recover()`` folds snapshot + journal tail into a request table,
    reports terminal requests as-is (their tokens are durable), and
    **resubmits every unfinished request** to a fresh engine
    incarnation. Already-durable tokens are re-delivered to the new
    stream instantly; the decode prefix is regenerated and *verified*
    against the journal (replay fidelity) but not re-emitted — the
    stream continues where it left off.
  * Under greedy sampling with the default (batch-independent) decode
    path, the regenerated stream is bit-identical to the uninterrupted
    run. Under temperature sampling, recovery restores the snapshot's
    scheduler RNG key, so two recoveries from the same artifacts are
    seed-identical (the interrupted run's future is not replayable —
    its key splits depended on lost batch composition).
"""
from __future__ import annotations

import queue
import threading
from collections import deque
from dataclasses import dataclass
from typing import Dict, List, Optional, Tuple

import jax.numpy as jnp
import numpy as np

from repro.serving.errors import (REASON_WALL, DeadlineUnmeetable,
                                  QueueFull, ShuttingDown, error_for_reason,
                                  validate_request)
from repro.serving.journal import (JournalWriter, Snapshot, fold_records,
                                   load_snapshot, read_journal,
                                   save_snapshot)
from repro.serving.scheduler import DONE, SHED

_END = "__end__"


def _tok_py(tok):
    """Scheduler token -> JSON-able (int, or list for audio frames)."""
    arr = np.asarray(tok)
    return int(arr) if arr.ndim == 0 else arr.tolist()


def _tok_eq(a, b) -> bool:
    return bool(np.array_equal(np.asarray(a), np.asarray(b)))


class TokenStream:
    """One request's token stream. Single-consumer: iterate for tokens
    as they become durable-visible, or block on ``result()`` for the
    full greedy-ordered array. Terminal state carries the structured
    finish reason; ``result()``/``raise_for_status()`` map non-completed
    reasons onto the serving error taxonomy."""

    def __init__(self, rid: int, prompt: np.ndarray, max_new_tokens: int,
                 spec: bool = False):
        self.rid = rid
        self.prompt = prompt
        self.max_new_tokens = max_new_tokens
        self.spec = spec
        self.tokens: List = []            # published (durable-visible)
        self.finish_reason: Optional[str] = None
        self.error: Optional[BaseException] = None
        self.replayed = 0                 # tokens restored from journal
        self.replay_mismatch = 0          # replay-fidelity violations
        self._q: "queue.SimpleQueue" = queue.SimpleQueue()
        self._done = threading.Event()

    # ------------------------------------------------- producer side ----

    def _push(self, tok) -> None:
        self.tokens.append(tok)
        self._q.put(tok)

    def _finish(self, reason: str) -> None:
        if self.finish_reason is None:
            self.finish_reason = reason
            self._done.set()
            self._q.put(_END)

    def _abort(self, exc: BaseException) -> None:
        """Crash path: no terminal reason — the stream ends with the
        crash exception so consumers never hang on a dead engine."""
        if self.finish_reason is None and self.error is None:
            self.error = exc
            self._done.set()
            self._q.put(_END)

    # ------------------------------------------------- consumer side ----

    @property
    def done(self) -> bool:
        return self._done.is_set()

    def __iter__(self):
        while True:
            item = self._q.get()
            if isinstance(item, str) and item == _END:
                return
            yield item

    def result(self, timeout: Optional[float] = None) -> np.ndarray:
        """Block until terminal; return the full token array for a
        completed request, else raise the taxonomy error for the finish
        reason (or the crash exception for an aborted stream)."""
        if not self._done.wait(timeout):
            raise TimeoutError(f"rid {self.rid} still streaming after "
                               f"{timeout}s")
        self.raise_for_status()
        return np.asarray(self.tokens)

    def raise_for_status(self) -> None:
        if self.error is not None:
            raise self.error
        exc = error_for_reason(self.finish_reason)
        if exc is not None:
            raise exc(f"rid {self.rid}: {self.finish_reason} "
                      f"after {len(self.tokens)} tokens")


@dataclass
class RecoveryReport:
    """What recover() found and did."""
    requests: int = 0            # journaled submits (admitted intents)
    terminal: int = 0            # already finished — reported, not replayed
    resumed: int = 0             # unfinished — resubmitted for replay
    torn_tail: bool = False      # journal ended in a truncated record
    corrupt_gaps: int = 0        # rids with a mid-file token gap (corrupt
                                 # journal; resumed from consistent prefix)
    snapshot_used: bool = False
    snapshot_round: int = -1
    journal_records: int = 0


class FrontDoor:
    """Async streaming front door over one Engine.

    Parameters beyond the engine/scheduler ones:

    journal_path       — WAL file; None disables durability.
    snapshot_path      — snapshot base path (``.npz``/``.json`` pair);
                         None disables snapshots (journal-only recovery).
    snapshot_every_rounds — snapshot cadence in fused decode rounds
                         (0 = never).
    fsync_every        — token-record fsync batch size.
    max_wall_s         — safety bound passed to Scheduler.run.

    Remaining keyword arguments go to Engine.make_scheduler (admission,
    deadlines infrastructure, faults, degrade, invariants, ...).
    """

    def __init__(self, engine, *, num_slots: int,
                 journal_path: Optional[str] = None,
                 snapshot_path: Optional[str] = None,
                 snapshot_every_rounds: int = 0,
                 fsync_every: int = 8,
                 max_wall_s: Optional[float] = None,
                 _journal_start_seq: int = 0,
                 **sched_kw):
        self._engine = engine
        self._faults = sched_kw.get("faults")
        self._sched = engine.make_scheduler(
            num_slots=num_slots, on_round=self._on_round, **sched_kw)
        self._max_wall_s = max_wall_s
        self._lock = threading.Lock()
        self._inbox: deque = deque()       # ("submit", stream) | ("cancel", rid)
        self.streams: Dict[int, TokenStream] = {}
        self._next_rid = 0                 # door/journal rid namespace
        self._alias: Dict[int, int] = {}   # scheduler rid -> door rid
        self._by_door_rid: Dict[int, object] = {}   # door rid -> RequestState
        self._consumed: Dict[int, int] = {}         # door rid -> sched toks seen
        self._replay: Dict[int, List] = {}          # door rid -> journaled prefix
        self._admitted: set = set()
        self._open = True
        self.crashed: Optional[BaseException] = None
        self.journal: Optional[JournalWriter] = None
        if journal_path is not None:
            self.journal = JournalWriter(journal_path,
                                         fsync_every=fsync_every,
                                         start_seq=_journal_start_seq)
        self._snap_path = snapshot_path
        self._snap_every = snapshot_every_rounds
        self._snap_idx = 0
        self._last_snap_round = 0
        self.snapshots_written = 0
        self._thread = threading.Thread(
            target=self._serve, name="frontdoor-serve", daemon=True)

    # ----------------------------------------------------- caller API ----

    def start(self) -> "FrontDoor":
        self._thread.start()
        return self

    def submit(self, prompt: np.ndarray, max_new_tokens: int, *,
               deadline_s: Optional[float] = None,
               ttft_deadline_s: Optional[float] = None,
               spec: Optional[bool] = None) -> TokenStream:
        """Submit a request; returns its TokenStream immediately.

        InvalidRequest raises synchronously (nothing journaled).
        Overload refusals (bounded queue / wait budget) surface on the
        stream: overload="reject" turns into QueueFull /
        DeadlineUnmeetable from ``result()``; overload="shed" into the
        structured shed reason. After drain() begins, raises
        ShuttingDown.

        ``spec`` opts the request into speculative decoding (requires a
        SpecScheduler; None = scheduler default). Resolved here so the
        journaled record carries a concrete bool — a spec=True submit on
        a non-spec scheduler raises synchronously, nothing journaled."""
        prompt = np.asarray(prompt)
        validate_request(
            int(prompt.shape[0]) if prompt.ndim else 0, max_new_tokens,
            cache_len=self._sched.cache_len, window=self._sched._window)
        spec = self._sched._resolve_spec(spec)
        with self._lock:
            if not self._open:
                raise ShuttingDown("front door is draining — admissions "
                                   "closed")
            rid = self._next_rid
            self._next_rid += 1
            stream = TokenStream(rid, prompt, max_new_tokens, spec=spec)
            self.streams[rid] = stream
            self._consumed[rid] = 0
            if self.journal is not None:
                self.journal.append(
                    "submit", rid=rid, prompt=prompt.tolist(),
                    max_new=max_new_tokens,
                    deadline_s=deadline_s,
                    ttft_deadline_s=ttft_deadline_s,
                    spec=spec)
            self._inbox.append(("submit", stream,
                                {"deadline_s": deadline_s,
                                 "ttft_deadline_s": ttft_deadline_s,
                                 "spec": spec}))
        return stream

    def cancel(self, rid: int) -> bool:
        """Request cancellation of a door rid (journaled; applied by the
        pump between fused rounds). False if already terminal."""
        with self._lock:
            stream = self.streams.get(rid)
            if stream is None or stream.done:
                return False
            # the crash path abandons (closes) the journal outside this
            # lock — a cancel racing it must not append to a dead WAL
            if self.journal is not None and not self.journal.closed:
                self.journal.append("cancel", rid=rid)
            self._inbox.append(("cancel", rid))
        return True

    def drain(self, timeout: Optional[float] = None) -> List[TokenStream]:
        """Graceful shutdown: stop admissions, run the batch (and queue)
        dry, seal the journal. Returns every stream, all terminal —
        unless the serve loop crashed, in which case unfinished streams
        are aborted with the crash exception (``self.crashed``)."""
        with self._lock:
            self._open = False
        if self._thread.is_alive() or not self._thread.ident:
            try:
                self._thread.join(timeout)
            except RuntimeError:          # never started: nothing to drain
                pass
        if self._thread.is_alive():
            raise TimeoutError(f"drain incomplete after {timeout}s")
        if self.journal is not None and not self.journal.closed:
            self.journal.append("drain", reason="graceful")
            self.journal.close()
        return [s for _, s in self._streams_items()]

    def replay_stats(self) -> Dict[str, float]:
        """Replay-fidelity census across recovered streams."""
        streams = [s for _, s in self._streams_items()]
        replayed = sum(s.replayed for s in streams)
        mism = sum(s.replay_mismatch for s in streams)
        return {"replayed_tokens": replayed, "mismatches": mism,
                "fidelity": 1.0 if replayed == 0
                else 1.0 - mism / replayed}

    # -------------------------------------------------- serving thread ----

    def _streams_items(self) -> List[Tuple[int, TokenStream]]:
        """Point-in-time copy of the stream table, rid-sorted. Caller
        threads insert under the lock, so every iteration — serving
        thread or census — must copy under it too (a bare iteration
        races dict resize)."""
        with self._lock:
            return sorted(self.streams.items())

    def _serve(self) -> None:
        try:
            self._sched.run(max_wall_s=self._max_wall_s,
                            keep_alive=self._tick)
            with self._lock:
                # close admissions BEFORE the final pump: a submit
                # accepted after it would sit in the inbox unserved and
                # its consumer would block forever
                self._open = False
            self._tick()                   # final publish + finish sweep
            # run() only returns with live streams via the max_wall_s
            # guard (or a final-tick admit the loop never decoded):
            # finish them as wall-shed so consumers never hang
            for rid, stream in self._streams_items():
                if not stream.done:
                    if self.journal is not None and not self.journal.closed:
                        self.journal.append("finish", rid=rid,
                                            reason=REASON_WALL,
                                            n_tokens=len(stream.tokens))
                    stream._finish(REASON_WALL)
        except BaseException as e:         # noqa: BLE001 — crash path
            self.crashed = e
            with self._lock:
                self._open = False         # dead engine: refuse admissions
            if self.journal is not None and not self.journal.closed:
                torn = self._faults.torn_tail_bytes() \
                    if self._faults is not None else 0
                # a real SIGKILL loses the buffered tail; a torn write
                # additionally leaves a partial record on disk
                self.journal.abandon(torn_bytes=torn)
            for _rid, stream in self._streams_items():
                stream._abort(e)
        finally:
            with self._lock:
                self._open = False

    def _tick(self) -> bool:
        """The pump: runs in the serving thread once per scheduler loop
        (keep_alive) and after every fused round (on_round)."""
        with self._lock:
            items = list(self._inbox)
            self._inbox.clear()
        for item in items:
            if item[0] == "submit":
                _, stream, kw = item
                try:
                    st = self._sched.submit(
                        stream.prompt, stream.max_new_tokens,
                        arrival_s=self._sched._now(), **kw)
                except (QueueFull, DeadlineUnmeetable) as e:
                    # overload="reject": surface the refusal on the
                    # stream (its taxonomy class survives via reason)
                    stream.error = e
                    stream._finish(
                        "shed_queue" if isinstance(e, QueueFull)
                        else "shed_est_wait")
                    if self.journal is not None:
                        self.journal.append("finish", rid=stream.rid,
                                            reason=stream.finish_reason,
                                            n_tokens=0)
                    continue
                self._alias[st.req.rid] = stream.rid
                self._by_door_rid[stream.rid] = st
            else:
                _, rid = item
                st = self._by_door_rid.get(rid)
                if st is not None:
                    self._sched.cancel(st.req.rid)
        self._publish()
        self._maybe_snapshot()
        return self._open

    def _on_round(self, sched, round_idx: int) -> None:
        self._tick()

    def _publish(self) -> None:
        """Diff scheduler states against streams: push fresh tokens
        (suppressing + verifying the replayed prefix), journal them,
        finish terminal streams."""
        for door_rid, st in self._by_door_rid.items():
            stream = self.streams[door_rid]
            if stream.done:
                continue
            seen = self._consumed[door_rid]
            fresh = st.tokens[seen:]
            if fresh:
                if door_rid not in self._admitted:
                    self._admitted.add(door_rid)
                    if self.journal is not None:
                        self.journal.append("admit", rid=door_rid)
                replay = self._replay.get(door_rid)
                out = []
                for tok in fresh:
                    i = seen
                    seen += 1
                    if replay is not None and i < len(replay):
                        # regenerated prefix: verify, do not re-emit
                        if not _tok_eq(tok, replay[i]):
                            stream.replay_mismatch += 1
                        continue
                    stream._push(np.asarray(tok))
                    out.append(_tok_py(tok))
                self._consumed[door_rid] = seen
                if out and self.journal is not None:
                    self.journal.append(
                        "token", rid=door_rid,
                        i=len(stream.tokens) - len(out), tok=out)
            if st.status in (DONE, SHED):
                if self.journal is not None:
                    self.journal.append("finish", rid=door_rid,
                                        reason=st.finish_reason,
                                        n_tokens=len(stream.tokens))
                stream._finish(st.finish_reason)

    def _maybe_snapshot(self) -> None:
        if self._snap_path is None or self._snap_every <= 0:
            return
        if self._sched._round_idx - self._last_snap_round < self._snap_every:
            return
        self._last_snap_round = self._sched._round_idx
        if self._faults is not None:
            self._faults.before_snapshot(self._snap_idx)   # may crash
        self._snap_idx += 1
        # flush first: the snapshot must only subsume DURABLE records
        if self.journal is not None:
            self.journal.flush()
        snap = self._gather_snapshot()
        save_snapshot(self._snap_path, snap)
        self.snapshots_written += 1
        if self.journal is not None:
            self.journal.append("snapshot", path=self._snap_path,
                                covers_seq=snap.seq, idx=self._snap_idx - 1)

    def _gather_snapshot(self) -> Snapshot:
        snap = Snapshot(next_rid=self._next_rid,
                        seq=self.journal.seq if self.journal else 0,
                        total_steps=self._sched.total_steps,
                        round_idx=self._sched._round_idx,
                        rng_key=np.asarray(self._sched._key))
        for rid, s in self._streams_items():
            snap.requests[rid] = {"prompt": s.prompt,
                                  "tokens": list(s.tokens),
                                  "max_new": s.max_new_tokens,
                                  "reason": s.finish_reason,
                                  "arrival_s": 0.0,
                                  "spec": s.spec}
            if s.finish_reason is None:
                snap.queue.append(rid)
        slot_rids = np.full(self._sched.num_slots, -1, np.int64)
        for i, st in enumerate(self._sched._slots):
            if st is not None:
                slot_rids[i] = self._alias.get(st.req.rid, -1)
        snap.slot_rids = slot_rids
        snap.slot_cur_len = np.asarray(self._sched._cache["cur_len"],
                                       np.int64)
        return snap


# ------------------------------------------------------------ recovery ----

def recover(engine, *, journal_path: str,
            snapshot_path: Optional[str] = None,
            num_slots: int,
            **door_kw) -> Tuple[FrontDoor, RecoveryReport]:
    """Cold-start a FrontDoor from a crashed incarnation's journal (+
    optional snapshot). Terminal requests are reported with their
    durable tokens; every unfinished admitted request is resubmitted
    for deterministic replay — its journaled tokens are re-delivered to
    the new stream immediately, the regenerated prefix is verified
    (replay fidelity) and fresh tokens continue the stream. The door is
    returned STARTED; callers stream/drain as usual.

    Deadlines are not re-armed on replay: the original budgets were
    relative to a wall clock that died with the process, and shedding a
    half-delivered stream on a stale deadline would turn one crash into
    two failures."""
    tail = read_journal(journal_path)
    if tail.torn:
        # repair: drop the torn fragment so the new incarnation's
        # appended records are reachable (the reader stops at the first
        # corrupt frame — anything after it would be invisible)
        with open(journal_path, "r+b") as f:
            f.truncate(tail.valid_bytes)
    snap = load_snapshot(snapshot_path) if snapshot_path else None
    table = fold_records(tail.records, base=snap)
    report = RecoveryReport(
        requests=len(table), torn_tail=tail.torn,
        corrupt_gaps=sum(1 for r in table.values() if r.get("token_gap")),
        snapshot_used=snap is not None,
        snapshot_round=snap.round_idx if snap else -1,
        journal_records=len(tail.records))
    door = FrontDoor(engine, num_slots=num_slots,
                     journal_path=journal_path,
                     snapshot_path=snapshot_path,
                     _journal_start_seq=tail.last_seq + 1,
                     **door_kw)
    if snap is not None and snap.rng_key is not None:
        door._sched._key = jnp.asarray(snap.rng_key)
    # a journaled spec request can only be replayed speculatively if the
    # new incarnation has a spec scheduler; otherwise degrade to plain
    # decode — greedy speculation is token-exact, so the regenerated
    # stream is bit-identical either way
    spec_capable = hasattr(door._sched, "_dcache")
    for rid in sorted(table):
        r = table[rid]
        spec = bool(r.get("spec", False)) and spec_capable
        stream = TokenStream(rid, np.asarray(r["prompt"]), r["max_new"],
                             spec=spec)
        door.streams[rid] = stream
        door._consumed[rid] = 0
        door._next_rid = max(door._next_rid, rid + 1)
        for tok in r["tokens"]:          # durable tokens: re-deliver now
            stream._push(np.asarray(tok))
        if r["reason"] is not None:      # terminal before the crash
            stream._finish(r["reason"])
            report.terminal += 1
            continue
        report.resumed += 1
        stream.replayed = len(r["tokens"])   # prefix to verify-regenerate
        door._replay[rid] = list(r["tokens"])
        door._inbox.append(("submit", stream,
                            {"deadline_s": None, "ttft_deadline_s": None,
                             "spec": spec}))
        if r.get("cancel_requested"):    # journaled but unapplied cancel
            door._inbox.append(("cancel", rid))
    return door.start(), report
