"""Token sampling from model logits."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def greedy(logits: jnp.ndarray) -> jnp.ndarray:
    """argmax over the vocab axis. (..., V) -> (...,) int32."""
    return jnp.argmax(logits, axis=-1).astype(jnp.int32)


def sample(logits: jnp.ndarray, key, *, temperature: float = 1.0,
           top_p: float = 1.0) -> jnp.ndarray:
    if temperature == 0.0:
        return greedy(logits)
    logits = jnp.asarray(logits, jnp.float32) / temperature
    # non-finite guard: jax.random.categorical on a row containing
    # NaN/Inf returns garbage silently. Clamp to the top_p mask fill
    # value so a poisoned row degrades to a uniform draw over the
    # finite entries (the fused scan quarantines it upstream anyway;
    # this keeps the lockstep/spec paths safe too). The greedy branch
    # above is untouched — bit-identical to the seed sampler.
    logits = jnp.where(jnp.isfinite(logits), logits, -1e30)
    if top_p < 1.0:
        sorted_l = jnp.sort(logits, axis=-1)[..., ::-1]
        probs = jax.nn.softmax(sorted_l, axis=-1)
        csum = jnp.cumsum(probs, axis=-1)
        cutoff_idx = jnp.sum(csum < top_p, axis=-1, keepdims=True)
        cutoff = jnp.take_along_axis(sorted_l, cutoff_idx, axis=-1)
        logits = jnp.where(logits < cutoff, -1e30, logits)
    return jax.random.categorical(key, logits, axis=-1).astype(jnp.int32)


def sample_step(logits: jnp.ndarray, key, *, temperature: float = 0.0,
                top_p: float = 1.0) -> jnp.ndarray:
    """On-device per-step sampler for the fused decode scan.

    temperature / top_p are Python floats (static under jit), so the
    greedy path traces to a plain argmax with no PRNG use — bit-identical
    to the host-side greedy() the lockstep engine calls. The key is
    threaded by the caller (one split per scanned step)."""
    if temperature == 0.0:
        return greedy(logits)
    return sample(logits, key, temperature=temperature, top_p=top_p)
