"""Structured serving error taxonomy + terminal finish reasons.

Production serving treats overload and partial failure as the common
case, so every way a request can end — or be refused entry — has one
canonical name here. Two kinds of outcome:

  * **Exceptions** (raised to the caller of ``submit()`` / ``run()``):

      code                  raised by                   meaning
      ----------------------------------------------------------------
      invalid_request       submit()/generate()         prompt/max_new
                                                        can't be served
      queue_full            submit(), overload="reject" bounded queue at
                                                        capacity
      deadline_unmeetable   submit(), overload="reject" estimated queue
                                                        wait > budget
      watchdog_timeout      internal retry loop         retries exhausted
                                                        on a step fault
      invariant             Scheduler.check_invariants  slot-state machine
                                                        corrupted
      shutting_down         FrontDoor.submit            door is draining
      deadline_exceeded     TokenStream.result          request shed on a
                                                        deadline
      cancelled             TokenStream.result          request cancelled
      request_failed        TokenStream.result          numerics / fault /
                                                        wall-timeout shed

    ``error_for_reason(reason)`` maps a terminal finish reason to the
    exception class the front door raises for it.

  * **Finish reasons** (``RequestState.finish_reason`` on terminal
    requests — the shed/termination side of the taxonomy):

      completed          reached max_new_tokens (status "done")
      cancelled          cancel(rid) — user abort, queued or mid-decode
      deadline_ttft      TTFT deadline expired while queued
      deadline_e2e       end-to-end deadline expired mid-decode
      shed_queue         bounded-queue admission control, overload="shed"
      shed_est_wait      estimated wait exceeded the admission budget
      numerics_nonfinite non-finite logits — quarantined out of the batch
      fault_unrecoverable step fault persisted past the retry budget
      run_wall_timeout   run(max_wall_s=...) guard fired

All reasons other than "completed" leave the request with status
"shed"; tokens produced before the terminal event are retained.
"""
from __future__ import annotations

from typing import Optional


class ServingError(Exception):
    """Base of the serving taxonomy; `code` is the stable identifier."""
    code = "serving"


class InvalidRequest(ServingError, ValueError):
    """Request can never be served (bad shape/budget) — reject at the door."""
    code = "invalid_request"


class QueueFull(ServingError):
    """Bounded admission queue at capacity (overload="reject")."""
    code = "queue_full"


class DeadlineUnmeetable(ServingError):
    """Estimated queue wait exceeds the admission budget."""
    code = "deadline_unmeetable"


class TransientFault(ServingError):
    """A retryable step failure (the watchdog retries with backoff)."""
    code = "transient_fault"


class WatchdogTimeout(ServingError):
    """Retry budget exhausted on a persistently failing step."""
    code = "watchdog_timeout"


class InvariantViolation(ServingError, AssertionError):
    """Scheduler slot-state machine / accounting corruption detected."""
    code = "invariant"


class DeadlineExceeded(ServingError):
    """A per-request TTFT or end-to-end deadline expired (the request
    was shed; raised by TokenStream.result() at the front door)."""
    code = "deadline_exceeded"


class RequestCancelled(ServingError):
    """The request was cancelled (cancel(rid)) before completing."""
    code = "cancelled"


class RequestFailed(ServingError):
    """The request terminated on a fault path (numerics quarantine,
    unrecoverable step fault, serve-loop wall timeout)."""
    code = "request_failed"


class ShuttingDown(ServingError):
    """The front door is draining — no new admissions."""
    code = "shutting_down"


# ---------------------------------------------------- finish reasons ------

REASON_COMPLETED = "completed"
REASON_CANCELLED = "cancelled"
REASON_DEADLINE_TTFT = "deadline_ttft"
REASON_DEADLINE_E2E = "deadline_e2e"
REASON_SHED_QUEUE = "shed_queue"
REASON_SHED_WAIT = "shed_est_wait"
REASON_NUMERICS = "numerics_nonfinite"
REASON_FAULT = "fault_unrecoverable"
REASON_WALL = "run_wall_timeout"

SHED_REASONS = (REASON_CANCELLED, REASON_DEADLINE_TTFT, REASON_DEADLINE_E2E,
                REASON_SHED_QUEUE, REASON_SHED_WAIT, REASON_NUMERICS,
                REASON_FAULT, REASON_WALL)


def error_for_reason(reason):
    """Map a terminal finish_reason to the taxonomy exception class a
    front-door stream raises for it — None for "completed". This is the
    over-the-wire surface of the scheduler's shed semantics: the same
    structured reason a co-located caller reads off RequestState."""
    return {
        REASON_COMPLETED: None,
        REASON_CANCELLED: RequestCancelled,
        REASON_DEADLINE_TTFT: DeadlineExceeded,
        REASON_DEADLINE_E2E: DeadlineExceeded,
        REASON_SHED_QUEUE: QueueFull,
        REASON_SHED_WAIT: DeadlineUnmeetable,
        REASON_NUMERICS: RequestFailed,
        REASON_FAULT: RequestFailed,
        REASON_WALL: RequestFailed,
    }.get(reason, RequestFailed)


def validate_request(prompt_len: int, max_new_tokens: int, *,
                     cache_len: int, window: Optional[int]) -> None:
    """Shared front-door validation for Scheduler.submit / Engine.generate.

    Rejects requests that would otherwise surface as a cache-splice
    shape error (or silent KV overwrite) deep in the decode path:
    the prompt plus every decode write must fit the per-slot cache
    extent when no rolling window bounds it.
    """
    if max_new_tokens < 1:
        raise InvalidRequest(
            f"max_new_tokens must be >= 1, got {max_new_tokens}")
    if prompt_len < 1:
        raise InvalidRequest(f"empty prompt (length {prompt_len})")
    if window is None and prompt_len > cache_len:
        raise InvalidRequest(
            f"prompt length {prompt_len} exceeds cache_len {cache_len}")
    if window is None and prompt_len + max_new_tokens - 1 > cache_len:
        raise InvalidRequest(
            f"prompt ({prompt_len}) + max_new_tokens ({max_new_tokens}) - 1 "
            f"= {prompt_len + max_new_tokens - 1} exceeds cache_len "
            f"{cache_len}; shrink the request or grow the cache")
