"""Fused on-device decode step for the continuous-batching scheduler.

The seed engine ran one jitted decode_step per token with a host round
trip (argmax on host, np.asarray sync, python loop bookkeeping) between
steps. Here the whole inner loop moves on device: sampling happens
inside the jitted function (PRNG keys threaded through the scan) and
``decode_steps_fused`` advances N tokens per dispatch as a lax.scan, so
the host is touched once per N tokens — exactly the cadence at which the
scheduler intervenes (admission / eviction / harvest).

Per-slot active masks make the fixed-size running batch safe: finished /
empty slots are compute-masked out of MoE routing (decode_step's
``active`` arg — no expert activation, no dispatch capacity, no XShare
selection influence), their cur_len does not advance, and their emitted
tokens are garbage the scheduler never reads.

Numerics quarantine: the scan checks every slot's last-position logits
for non-finite values *before* sampling. A poisoned slot is frozen on
the spot — token held, cur_len not advanced, compute-masked out of
routing from the next step — and reported to the scheduler via the
returned ``poisoned`` mask, so one NaN terminates one request instead
of the whole fused batch. Detection is a pure elementwise pass over
logits already materialized; on a healthy batch every guard `where`
is the identity, keeping the fault-free path bit-exact.

build_step_fns() bundles every compiled function the scheduler needs;
jit retraces per input shape, so one bundle serves any batch size /
prompt length.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, XSharePolicy
from repro.core.selection import gate_histogram
from repro.models import decode_step, embed_tokens, prefill
from repro.models.layers import rms_norm
from repro.models.model import evict_slot, insert_request
from repro.models.moe import OFF
from repro.serving.sampler import sample_step
from repro.serving.spec_decode import greedy_accept

NO_FAULT = (-1, -1)   # disabled (slot, step) NaN-injection operand


def decode_steps_fused(cfg: ArchConfig, params, tok: jnp.ndarray,
                       cache: dict, remaining: jnp.ndarray, key, *,
                       num_steps: int,
                       policy: XSharePolicy = OFF,
                       temperature: float = 0.0,
                       force_window: Optional[int] = None,
                       capacity_factor: float = 8.0,
                       dispatch: str = "auto",
                       fault: Optional[jnp.ndarray] = None):
    """Run `num_steps` decode+sample steps as one on-device lax.scan.

    tok: (B,) int32 — each slot's last emitted token ((B, K) audio).
    remaining: (B,) int32 — tokens each slot still owes (0 = empty /
    evicted slot). The per-step active mask is `remaining > 0` and
    decrements on device, so a slot that reaches its budget MID-CHUNK
    deactivates on the very next step: its rows stop feeding XShare
    batch selection and the activation metrics, and its cache cur_len
    freezes. Evicted slots stay inert no matter how many scans pass
    before a new request is inserted over them.

    dispatch: MoE expert-compute path (models/moe.py) — the fused scan
    and the dense decode fast path unify behind this one switch
    ("auto": dense off-mesh at decode sizes, sorted grouped-GEMM
    dispatch elsewhere).

    fault: optional (2,) int32 (slot, step-in-chunk) — the fault-
    injection harness (serving/faults.py) poisons that slot's logits
    with NaN at that step. A *traced* operand, so fault campaigns and
    production runs share one compiled scan; (-1, -1) disables it.

    Returns (tok', cache', toks (num_steps, B[, K]), aux,
    ok (num_steps, B) bool, poisoned (B,) bool): `ok[i, b]` marks a
    real harvested token (slot active and finite at step i), `poisoned`
    flags slots quarantined for non-finite logits.
    """
    B = tok.shape[0]
    fault = jnp.asarray(NO_FAULT if fault is None else fault, jnp.int32)

    def body(carry, step_i):
        tok, cache, remaining, poisoned, key = carry
        active = (remaining > 0) & ~poisoned
        cur0 = cache["cur_len"]
        lg, cache, aux = decode_step(
            cfg, params, tok[:, None], cache, policy=policy,
            force_window=force_window, capacity_factor=capacity_factor,
            active=active, dispatch=dispatch)
        last = lg[:, -1]                          # (B, V) or (B, K, V)
        inject = (jnp.arange(B) == fault[0]) & (step_i == fault[1])
        last = jnp.where(inject.reshape((B,) + (1,) * (last.ndim - 1)),
                         jnp.nan, last)
        finite = jnp.isfinite(last).reshape(B, -1).all(axis=1)
        ok = active & finite                      # (B,) harvestable step
        poisoned = poisoned | (active & ~finite)
        key, sub = jax.random.split(key)
        nxt = sample_step(last, sub, temperature=temperature)
        okm = ok if tok.ndim == 1 else ok[:, None]
        nxt = jnp.where(okm, nxt, tok)
        cache["cur_len"] = jnp.where(ok, cur0 + 1, cur0)
        remaining = remaining - ok.astype(remaining.dtype)
        return (nxt, cache, remaining, poisoned, key), (nxt, ok, aux)

    # modest unroll: fewer while-loop trips and better cross-step fusion
    # without blowing up compile time for large chunks
    carry0 = (tok, cache, remaining, jnp.zeros((B,), bool), key)
    (tok, cache, remaining, poisoned, key), (toks, oks, aux) = jax.lax.scan(
        body, carry0, jnp.arange(num_steps, dtype=jnp.int32),
        unroll=min(4, num_steps))
    return tok, cache, toks, aux, oks, poisoned


def spec_steps_fused(cfg: ArchConfig, params, dcfg: ArchConfig, dparams,
                     tok: jnp.ndarray, cache: dict, dcache: dict,
                     remaining: jnp.ndarray, budget: jnp.ndarray,
                     draft_len: jnp.ndarray, spec_on: jnp.ndarray,
                     priors: jnp.ndarray, *,
                     num_rounds: int, spec_len: int,
                     policy: XSharePolicy = OFF,
                     force_window: Optional[int] = None,
                     capacity_factor: float = 8.0,
                     dispatch: str = "auto",
                     fault: Optional[jnp.ndarray] = None):
    """Fused draft-then-verify: `num_rounds` speculative rounds as one
    on-device lax.scan, speculative and plain requests sharing one
    running batch.

    Each round drafts up to `spec_len` tokens per slot with the draft
    model (inner lax.scan of spec_len+1 steps — the extra step writes
    the last draft's KV, mirroring the lockstep reference), then runs
    ONE target verify pass over (B, 1+spec_len) tokens — the paper's
    amplified batch shape — routed with XSharePolicy(mode="spec") and
    the scheduler's per-slot correlation priors. Ragged acceptance
    (greedy_accept with a per-slot `limit`) rolls both caches back to
    cur0 + num_new, so draft and target cur_len stay equal for every
    speculative slot.

    Heterogeneous batches fall out of the per-slot limit
    ``lim = min(draft_len, remaining-1, budget)`` (zeroed for inactive
    or non-speculative slots): a slot with lim == 0 degenerates exactly
    to plain greedy decode — accepted 0, one bonus token from the
    verify pass's position-0 logits — so plain requests ride the same
    dispatch. Speculative slots with an exhausted budget keep drafting
    through the draft scan (dactive) so their draft cache stays in
    lockstep with the target cache, but accept nothing (lim == 0).

    tok: (B,) each slot's last emitted (uncached) token.
    remaining: (B,) tokens still owed (0 = empty slot).
    budget: (B,) draft tokens each slot may still spend.
    draft_len: (B,) per-slot adaptive draft length, <= spec_len.
    spec_on: (B,) bool — slot runs the draft model.
    priors: (B, E) gate-histogram correlation priors ((B, 0) when the
    target has no router).
    fault: optional (2,) int32 (slot, round-in-chunk) NaN injection into
    that round's verify logits, as in decode_steps_fused.

    Returns (tok', cache', dcache', remaining', budget',
    new_tokens (R, B, spec_len+1), num_new (R, B), accepted (R, B),
    drafted (R, B), aux, poisoned (B,)): harvest row r of slot b with
    ``new_tokens[r, b, :num_new[r, b]]``. num_new never exceeds the
    slot's remaining budget (the -1 in lim reserves room for the bonus
    token), so harvested tokens need no overshoot trimming.
    """
    B = tok.shape[0]
    fault = jnp.asarray(NO_FAULT if fault is None else fault, jnp.int32)
    use_priors = priors.shape[-1] > 0

    def round_body(carry, round_i):
        tok, cache, dcache, remaining, budget, poisoned = carry
        active = (remaining > 0) & ~poisoned
        dactive = active & spec_on
        lim = jnp.minimum(jnp.minimum(draft_len,
                                      jnp.maximum(remaining - 1, 0)),
                          budget)
        lim = jnp.where(dactive, lim, 0).astype(jnp.int32)

        # -- draft spec_len tokens (one extra step writes the last KV) --
        def draft_body(c, _):
            dtok, dcache = c
            dcur0 = dcache["cur_len"]
            dlg, dcache, _ = decode_step(
                dcfg, dparams, dtok[:, None], dcache,
                capacity_factor=capacity_factor, active=dactive,
                dispatch=dispatch)
            nxt = jnp.argmax(dlg[:, -1], axis=-1).astype(jnp.int32)
            nxt = jnp.where(dactive, nxt, dtok)
            dcache["cur_len"] = jnp.where(dactive, dcur0 + 1, dcur0)
            return (nxt, dcache), nxt

        dstart = dcache["cur_len"]
        (_, dcache), douts = jax.lax.scan(
            draft_body, (tok, dcache), None, length=spec_len + 1)
        drafts = douts[:spec_len].T                     # (B, spec_len)

        # -- single verify pass over (B, 1+spec_len) ---------------------
        verify_in = jnp.concatenate([tok[:, None], drafts], axis=1)
        cur0 = cache["cur_len"]
        vlg, cache, aux = decode_step(
            cfg, params, verify_in, cache, policy=policy,
            spec_shape=(B, 1 + spec_len), force_window=force_window,
            capacity_factor=capacity_factor, active=active,
            dispatch=dispatch,
            spec_priors=(priors * active[:, None] if use_priors else None))
        inject = (jnp.arange(B) == fault[0]) & (round_i == fault[1])
        vlg = jnp.where(inject[:, None, None], jnp.nan, vlg)
        finite = jnp.isfinite(vlg).reshape(B, -1).all(axis=1)
        ok = active & finite
        poisoned = poisoned | (active & ~finite)

        res = greedy_accept(vlg, drafts, limit=lim)
        num_new = jnp.where(ok, res.num_new, 0).astype(jnp.int32)
        # ragged rollback: both caches advance by this round's emission;
        # verify KV written above cur0+num_new is dead and overwritten
        # by later rounds (same as inactive rows on the plain path)
        cache["cur_len"] = cur0 + num_new
        dcache["cur_len"] = jnp.where(dactive, dstart + num_new,
                                      dcache["cur_len"])
        x0 = jnp.take_along_axis(res.new_tokens, res.accepted[:, None],
                                 axis=1)[:, 0]
        tok = jnp.where(ok, x0, tok)
        remaining = remaining - num_new
        budget = budget - jnp.where(dactive, lim, 0)
        outs = (res.new_tokens, num_new, res.accepted.astype(jnp.int32),
                lim, aux)
        return (tok, cache, dcache, remaining, budget, poisoned), outs

    carry0 = (tok, cache, dcache, remaining, budget, jnp.zeros((B,), bool))
    (tok, cache, dcache, remaining, budget, poisoned), \
        (new_tokens, num_new, accepted, drafted, aux) = jax.lax.scan(
            round_body, carry0, jnp.arange(num_rounds, dtype=jnp.int32))
    return (tok, cache, dcache, remaining, budget,
            new_tokens, num_new, accepted, drafted, aux, poisoned)


def gate_probe(cfg: ArchConfig, params, tokens: jnp.ndarray) -> jnp.ndarray:
    """Cheap router probe: a request's expert gate histogram (E,).

    Embeds the prompt and runs only the *first MoE layer's* router on the
    (pre-attention) hidden states — no attention, no FFN, no cache — so
    the scheduler can score a waiting request's expert affinity without
    paying for a prefill. An approximation of the true decode-time gate
    histogram, but the domain signal the admission policy needs (which
    experts a request leans on) is already present at the embedding.
    """
    x = embed_tokens(cfg, params, tokens)              # (B, S, d)
    h = rms_norm(x, params["layers"]["moe_norm"][0], cfg.norm_eps)
    wg = jnp.asarray(params["layers"]["moe"]["wg"][0], jnp.float32)
    probs = jax.nn.softmax(jnp.asarray(h, jnp.float32) @ wg, axis=-1)
    return gate_histogram(probs).mean(axis=0)          # (E,)


@dataclass
class StepFns:
    """Compiled serving functions shared by Engine and Scheduler."""
    prefill: Callable        # (params, tokens)            -> (lg, cache, aux)
    fused: Callable          # (params, tok, cache, remaining, key, fault)
    #                        -> (tok', cache', toks, aux, ok, poisoned)
    insert: Callable         # (cache, req_cache, slot)    -> cache
    evict: Callable          # (cache, slot)               -> cache
    evict_scrub: Callable    # (cache, slot) -> cache, row zeroed (poisoned)
    probe: Optional[Callable]  # (params, tokens) -> (E,) | None (no MoE)
    decode_chunk: int


def make_fused(cfg: ArchConfig, *,
               policy: XSharePolicy = OFF,
               decode_chunk: int = 8,
               temperature: float = 0.0,
               force_window: Optional[int] = None,
               capacity_factor: float = 8.0,
               dispatch: str = "auto") -> Callable:
    """One jitted fused-scan closure. Split out of build_step_fns so the
    scheduler's graceful-degradation ladder can compile variants with a
    tightened XShare policy while sharing the rest of the bundle."""
    return jax.jit(lambda p, tok, c, rem, key, fault: decode_steps_fused(
        cfg, p, tok, c, rem, key, num_steps=decode_chunk, policy=policy,
        temperature=temperature, force_window=force_window,
        capacity_factor=capacity_factor, dispatch=dispatch, fault=fault))


def build_step_fns(cfg: ArchConfig, *,
                   policy: XSharePolicy = OFF,
                   cache_len: int = 512,
                   decode_chunk: int = 8,
                   temperature: float = 0.0,
                   force_window: Optional[int] = None,
                   capacity_factor: float = 8.0,
                   dispatch: str = "auto") -> StepFns:
    """Build the jitted function bundle for one (model config, serving
    config) pair. decode_chunk is the N of decode_steps_fused — the
    number of tokens generated between scheduler interventions."""
    pre = jax.jit(lambda p, t: prefill(
        cfg, p, t, cache_len=cache_len, policy=OFF,
        force_window=force_window, capacity_factor=capacity_factor,
        dispatch=dispatch))
    fused = make_fused(cfg, policy=policy, decode_chunk=decode_chunk,
                       temperature=temperature, force_window=force_window,
                       capacity_factor=capacity_factor, dispatch=dispatch)
    probe = None
    if cfg.family == "moe":
        probe = jax.jit(lambda p, t: gate_probe(cfg, p, t))
    return StepFns(prefill=pre, fused=fused,
                   insert=jax.jit(insert_request), evict=jax.jit(evict_slot),
                   evict_scrub=jax.jit(
                       lambda c, s: evict_slot(c, s, scrub=True)),
                   probe=probe, decode_chunk=decode_chunk)


# ------------------------------------------------- speculative bundle ----

@dataclass
class SpecStepFns:
    """Compiled speculative-decoding functions layered on top of a
    StepFns bundle (serving/spec_scheduler.py drives both)."""
    dprefill: Callable   # (dparams, tokens) -> (lg, dcache, aux)
    fused: Callable      # (p, dp, tok, cache, dcache, remaining, budget,
    #                       draft_len, spec_on, priors, fault) -> 11-tuple
    spec_len: int        # max draft tokens per round (static)
    num_rounds: int      # draft-verify rounds per dispatch (static)


def make_spec_fused(cfg: ArchConfig, dcfg: ArchConfig, *,
                    policy: XSharePolicy = OFF,
                    spec_len: int,
                    num_rounds: int,
                    force_window: Optional[int] = None,
                    capacity_factor: float = 8.0,
                    dispatch: str = "auto") -> Callable:
    """One jitted fused spec-scan closure (split out, like make_fused,
    so the degradation ladder can compile tightened-policy variants)."""
    return jax.jit(lambda p, dp, tok, c, dc, rem, bud, dl, so, pri, fault:
                   spec_steps_fused(
                       cfg, p, dcfg, dp, tok, c, dc, rem, bud, dl, so, pri,
                       num_rounds=num_rounds, spec_len=spec_len,
                       policy=policy, force_window=force_window,
                       capacity_factor=capacity_factor, dispatch=dispatch,
                       fault=fault))


def build_spec_fns(cfg: ArchConfig, dcfg: ArchConfig, *,
                   policy: XSharePolicy = OFF,
                   spec_len: int,
                   num_rounds: int = 4,
                   cache_len: int = 512,
                   force_window: Optional[int] = None,
                   capacity_factor: float = 8.0,
                   dispatch: str = "auto") -> SpecStepFns:
    """Speculative bundle for one (target, draft) model pair. `policy`
    must already be spec-compatible (mode "off" or "spec" — the Engine
    maps other modes to OFF for the verify pass, mirroring _verify)."""
    dpre = jax.jit(lambda p, t: prefill(
        dcfg, p, t, cache_len=cache_len,
        capacity_factor=capacity_factor))
    fused = make_spec_fused(cfg, dcfg, policy=policy, spec_len=spec_len,
                            num_rounds=num_rounds,
                            force_window=force_window,
                            capacity_factor=capacity_factor,
                            dispatch=dispatch)
    return SpecStepFns(dprefill=dpre, fused=fused, spec_len=spec_len,
                       num_rounds=num_rounds)
