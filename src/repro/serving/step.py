"""Fused on-device decode step for the continuous-batching scheduler.

The seed engine ran one jitted decode_step per token with a host round
trip (argmax on host, np.asarray sync, python loop bookkeeping) between
steps. Here the whole inner loop moves on device: sampling happens
inside the jitted function (PRNG keys threaded through the scan) and
``decode_steps_fused`` advances N tokens per dispatch as a lax.scan, so
the host is touched once per N tokens — exactly the cadence at which the
scheduler intervenes (admission / eviction / harvest).

Per-slot active masks make the fixed-size running batch safe: finished /
empty slots are compute-masked out of MoE routing (decode_step's
``active`` arg — no expert activation, no dispatch capacity, no XShare
selection influence), their cur_len does not advance, and their emitted
tokens are garbage the scheduler never reads.

Numerics quarantine: the scan checks every slot's last-position logits
for non-finite values *before* sampling. A poisoned slot is frozen on
the spot — token held, cur_len not advanced, compute-masked out of
routing from the next step — and reported to the scheduler via the
returned ``poisoned`` mask, so one NaN terminates one request instead
of the whole fused batch. Detection is a pure elementwise pass over
logits already materialized; on a healthy batch every guard `where`
is the identity, keeping the fault-free path bit-exact.

build_step_fns() bundles every compiled function the scheduler needs;
jit retraces per input shape, so one bundle serves any batch size /
prompt length.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, XSharePolicy
from repro.core.selection import gate_histogram
from repro.models import decode_step, embed_tokens, prefill
from repro.models.layers import rms_norm
from repro.models.model import evict_slot, insert_request
from repro.models.moe import OFF
from repro.serving.sampler import sample_step

NO_FAULT = (-1, -1)   # disabled (slot, step) NaN-injection operand


def decode_steps_fused(cfg: ArchConfig, params, tok: jnp.ndarray,
                       cache: dict, remaining: jnp.ndarray, key, *,
                       num_steps: int,
                       policy: XSharePolicy = OFF,
                       temperature: float = 0.0,
                       force_window: Optional[int] = None,
                       capacity_factor: float = 8.0,
                       dispatch: str = "auto",
                       fault: Optional[jnp.ndarray] = None):
    """Run `num_steps` decode+sample steps as one on-device lax.scan.

    tok: (B,) int32 — each slot's last emitted token ((B, K) audio).
    remaining: (B,) int32 — tokens each slot still owes (0 = empty /
    evicted slot). The per-step active mask is `remaining > 0` and
    decrements on device, so a slot that reaches its budget MID-CHUNK
    deactivates on the very next step: its rows stop feeding XShare
    batch selection and the activation metrics, and its cache cur_len
    freezes. Evicted slots stay inert no matter how many scans pass
    before a new request is inserted over them.

    dispatch: MoE expert-compute path (models/moe.py) — the fused scan
    and the dense decode fast path unify behind this one switch
    ("auto": dense off-mesh at decode sizes, sorted grouped-GEMM
    dispatch elsewhere).

    fault: optional (2,) int32 (slot, step-in-chunk) — the fault-
    injection harness (serving/faults.py) poisons that slot's logits
    with NaN at that step. A *traced* operand, so fault campaigns and
    production runs share one compiled scan; (-1, -1) disables it.

    Returns (tok', cache', toks (num_steps, B[, K]), aux,
    ok (num_steps, B) bool, poisoned (B,) bool): `ok[i, b]` marks a
    real harvested token (slot active and finite at step i), `poisoned`
    flags slots quarantined for non-finite logits.
    """
    B = tok.shape[0]
    fault = jnp.asarray(NO_FAULT if fault is None else fault, jnp.int32)

    def body(carry, step_i):
        tok, cache, remaining, poisoned, key = carry
        active = (remaining > 0) & ~poisoned
        cur0 = cache["cur_len"]
        lg, cache, aux = decode_step(
            cfg, params, tok[:, None], cache, policy=policy,
            force_window=force_window, capacity_factor=capacity_factor,
            active=active, dispatch=dispatch)
        last = lg[:, -1]                          # (B, V) or (B, K, V)
        inject = (jnp.arange(B) == fault[0]) & (step_i == fault[1])
        last = jnp.where(inject.reshape((B,) + (1,) * (last.ndim - 1)),
                         jnp.nan, last)
        finite = jnp.isfinite(last).reshape(B, -1).all(axis=1)
        ok = active & finite                      # (B,) harvestable step
        poisoned = poisoned | (active & ~finite)
        key, sub = jax.random.split(key)
        nxt = sample_step(last, sub, temperature=temperature)
        okm = ok if tok.ndim == 1 else ok[:, None]
        nxt = jnp.where(okm, nxt, tok)
        cache["cur_len"] = jnp.where(ok, cur0 + 1, cur0)
        remaining = remaining - ok.astype(remaining.dtype)
        return (nxt, cache, remaining, poisoned, key), (nxt, ok, aux)

    # modest unroll: fewer while-loop trips and better cross-step fusion
    # without blowing up compile time for large chunks
    carry0 = (tok, cache, remaining, jnp.zeros((B,), bool), key)
    (tok, cache, remaining, poisoned, key), (toks, oks, aux) = jax.lax.scan(
        body, carry0, jnp.arange(num_steps, dtype=jnp.int32),
        unroll=min(4, num_steps))
    return tok, cache, toks, aux, oks, poisoned


def gate_probe(cfg: ArchConfig, params, tokens: jnp.ndarray) -> jnp.ndarray:
    """Cheap router probe: a request's expert gate histogram (E,).

    Embeds the prompt and runs only the *first MoE layer's* router on the
    (pre-attention) hidden states — no attention, no FFN, no cache — so
    the scheduler can score a waiting request's expert affinity without
    paying for a prefill. An approximation of the true decode-time gate
    histogram, but the domain signal the admission policy needs (which
    experts a request leans on) is already present at the embedding.
    """
    x = embed_tokens(cfg, params, tokens)              # (B, S, d)
    h = rms_norm(x, params["layers"]["moe_norm"][0], cfg.norm_eps)
    wg = jnp.asarray(params["layers"]["moe"]["wg"][0], jnp.float32)
    probs = jax.nn.softmax(jnp.asarray(h, jnp.float32) @ wg, axis=-1)
    return gate_histogram(probs).mean(axis=0)          # (E,)


@dataclass
class StepFns:
    """Compiled serving functions shared by Engine and Scheduler."""
    prefill: Callable        # (params, tokens)            -> (lg, cache, aux)
    fused: Callable          # (params, tok, cache, remaining, key, fault)
    #                        -> (tok', cache', toks, aux, ok, poisoned)
    insert: Callable         # (cache, req_cache, slot)    -> cache
    evict: Callable          # (cache, slot)               -> cache
    evict_scrub: Callable    # (cache, slot) -> cache, row zeroed (poisoned)
    probe: Optional[Callable]  # (params, tokens) -> (E,) | None (no MoE)
    decode_chunk: int


def make_fused(cfg: ArchConfig, *,
               policy: XSharePolicy = OFF,
               decode_chunk: int = 8,
               temperature: float = 0.0,
               force_window: Optional[int] = None,
               capacity_factor: float = 8.0,
               dispatch: str = "auto") -> Callable:
    """One jitted fused-scan closure. Split out of build_step_fns so the
    scheduler's graceful-degradation ladder can compile variants with a
    tightened XShare policy while sharing the rest of the bundle."""
    return jax.jit(lambda p, tok, c, rem, key, fault: decode_steps_fused(
        cfg, p, tok, c, rem, key, num_steps=decode_chunk, policy=policy,
        temperature=temperature, force_window=force_window,
        capacity_factor=capacity_factor, dispatch=dispatch, fault=fault))


def build_step_fns(cfg: ArchConfig, *,
                   policy: XSharePolicy = OFF,
                   cache_len: int = 512,
                   decode_chunk: int = 8,
                   temperature: float = 0.0,
                   force_window: Optional[int] = None,
                   capacity_factor: float = 8.0,
                   dispatch: str = "auto") -> StepFns:
    """Build the jitted function bundle for one (model config, serving
    config) pair. decode_chunk is the N of decode_steps_fused — the
    number of tokens generated between scheduler interventions."""
    pre = jax.jit(lambda p, t: prefill(
        cfg, p, t, cache_len=cache_len, policy=OFF,
        force_window=force_window, capacity_factor=capacity_factor,
        dispatch=dispatch))
    fused = make_fused(cfg, policy=policy, decode_chunk=decode_chunk,
                       temperature=temperature, force_window=force_window,
                       capacity_factor=capacity_factor, dispatch=dispatch)
    probe = None
    if cfg.family == "moe":
        probe = jax.jit(lambda p, t: gate_probe(cfg, p, t))
    return StepFns(prefill=pre, fused=fused,
                   insert=jax.jit(insert_request), evict=jax.jit(evict_slot),
                   evict_scrub=jax.jit(
                       lambda c, s: evict_slot(c, s, scrub=True)),
                   probe=probe, decode_chunk=decode_chunk)
