"""Fused on-device decode step for the continuous-batching scheduler.

The seed engine ran one jitted decode_step per token with a host round
trip (argmax on host, np.asarray sync, python loop bookkeeping) between
steps. Here the whole inner loop moves on device: sampling happens
inside the jitted function (PRNG keys threaded through the scan) and
``decode_steps_fused`` advances N tokens per dispatch as a lax.scan, so
the host is touched once per N tokens — exactly the cadence at which the
scheduler intervenes (admission / eviction / harvest).

Per-slot active masks make the fixed-size running batch safe: finished /
empty slots are compute-masked out of MoE routing (decode_step's
``active`` arg — no expert activation, no dispatch capacity, no XShare
selection influence), their cur_len does not advance, and their emitted
tokens are garbage the scheduler never reads.

build_step_fns() bundles every compiled function the scheduler needs;
jit retraces per input shape, so one bundle serves any batch size /
prompt length.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, XSharePolicy
from repro.core.selection import gate_histogram
from repro.models import decode_step, embed_tokens, prefill
from repro.models.layers import rms_norm
from repro.models.model import evict_slot, insert_request
from repro.models.moe import OFF
from repro.serving.sampler import sample_step


def decode_steps_fused(cfg: ArchConfig, params, tok: jnp.ndarray,
                       cache: dict, remaining: jnp.ndarray, key, *,
                       num_steps: int,
                       policy: XSharePolicy = OFF,
                       temperature: float = 0.0,
                       force_window: Optional[int] = None,
                       capacity_factor: float = 8.0,
                       dispatch: str = "auto"):
    """Run `num_steps` decode+sample steps as one on-device lax.scan.

    tok: (B,) int32 — each slot's last emitted token ((B, K) audio).
    remaining: (B,) int32 — tokens each slot still owes (0 = empty /
    evicted slot). The per-step active mask is `remaining > 0` and
    decrements on device, so a slot that reaches its budget MID-CHUNK
    deactivates on the very next step: its rows stop feeding XShare
    batch selection and the activation metrics, and its cache cur_len
    freezes. Evicted slots stay inert no matter how many scans pass
    before a new request is inserted over them.

    dispatch: MoE expert-compute path (models/moe.py) — the fused scan
    and the dense decode fast path unify behind this one switch
    ("auto": dense off-mesh at decode sizes, sorted grouped-GEMM
    dispatch elsewhere).

    Returns (tok', cache', toks (num_steps, B[, K]), aux) where aux is
    the decode_step aux pytree stacked over steps (moe: (num_steps, L)
    per metric).
    """
    def body(carry, _):
        tok, cache, remaining, key = carry
        active = remaining > 0
        amask = active if tok.ndim == 1 else active[:, None]
        cur0 = cache["cur_len"]
        lg, cache, aux = decode_step(
            cfg, params, tok[:, None], cache, policy=policy,
            force_window=force_window, capacity_factor=capacity_factor,
            active=active, dispatch=dispatch)
        key, sub = jax.random.split(key)
        nxt = sample_step(lg[:, -1], sub, temperature=temperature)
        nxt = jnp.where(amask, nxt, tok)
        cache["cur_len"] = jnp.where(active, cur0 + 1, cur0)
        remaining = remaining - active.astype(remaining.dtype)
        return (nxt, cache, remaining, key), (nxt, aux)

    # modest unroll: fewer while-loop trips and better cross-step fusion
    # without blowing up compile time for large chunks
    (tok, cache, remaining, key), (toks, aux) = jax.lax.scan(
        body, (tok, cache, remaining, key), None, length=num_steps,
        unroll=min(4, num_steps))
    return tok, cache, toks, aux


def gate_probe(cfg: ArchConfig, params, tokens: jnp.ndarray) -> jnp.ndarray:
    """Cheap router probe: a request's expert gate histogram (E,).

    Embeds the prompt and runs only the *first MoE layer's* router on the
    (pre-attention) hidden states — no attention, no FFN, no cache — so
    the scheduler can score a waiting request's expert affinity without
    paying for a prefill. An approximation of the true decode-time gate
    histogram, but the domain signal the admission policy needs (which
    experts a request leans on) is already present at the embedding.
    """
    x = embed_tokens(cfg, params, tokens)              # (B, S, d)
    h = rms_norm(x, params["layers"]["moe_norm"][0], cfg.norm_eps)
    wg = jnp.asarray(params["layers"]["moe"]["wg"][0], jnp.float32)
    probs = jax.nn.softmax(jnp.asarray(h, jnp.float32) @ wg, axis=-1)
    return gate_histogram(probs).mean(axis=0)          # (E,)


@dataclass
class StepFns:
    """Compiled serving functions shared by Engine and Scheduler."""
    prefill: Callable        # (params, tokens)            -> (lg, cache, aux)
    fused: Callable          # (params, tok, cache, remaining, key)
    #                        -> (tok', cache', toks, aux)
    insert: Callable         # (cache, req_cache, slot)    -> cache
    evict: Callable          # (cache, slot)               -> cache
    probe: Optional[Callable]  # (params, tokens) -> (E,) | None (no MoE)
    decode_chunk: int


def build_step_fns(cfg: ArchConfig, *,
                   policy: XSharePolicy = OFF,
                   cache_len: int = 512,
                   decode_chunk: int = 8,
                   temperature: float = 0.0,
                   force_window: Optional[int] = None,
                   capacity_factor: float = 8.0,
                   dispatch: str = "auto") -> StepFns:
    """Build the jitted function bundle for one (model config, serving
    config) pair. decode_chunk is the N of decode_steps_fused — the
    number of tokens generated between scheduler interventions."""
    pre = jax.jit(lambda p, t: prefill(
        cfg, p, t, cache_len=cache_len, policy=OFF,
        force_window=force_window, capacity_factor=capacity_factor,
        dispatch=dispatch))
    fused = jax.jit(lambda p, tok, c, rem, key: decode_steps_fused(
        cfg, p, tok, c, rem, key, num_steps=decode_chunk, policy=policy,
        temperature=temperature, force_window=force_window,
        capacity_factor=capacity_factor, dispatch=dispatch))
    probe = None
    if cfg.family == "moe":
        probe = jax.jit(lambda p, t: gate_probe(cfg, p, t))
    return StepFns(prefill=pre, fused=fused,
                   insert=jax.jit(insert_request), evict=jax.jit(evict_slot),
                   probe=probe, decode_chunk=decode_chunk)
