from repro.serving.engine import Engine, GenStats  # noqa: F401
from repro.serving.errors import (  # noqa: F401
    DeadlineUnmeetable, InvalidRequest, InvariantViolation, QueueFull,
    ServingError, TransientFault, WatchdogTimeout,
)
from repro.serving.faults import (  # noqa: F401
    Fault, FaultInjector, InjectedFault, sample_campaign,
)
from repro.serving.scheduler import (  # noqa: F401
    Request, RequestState, Scheduler, tighten_policy,
)
from repro.serving.step import (  # noqa: F401
    StepFns, build_step_fns, decode_steps_fused, gate_probe, make_fused,
)
from repro.serving.spec_decode import (  # noqa: F401
    greedy_accept, rollback_cur_len, SpecResult,
)
from repro.serving import errors, faults, sampler  # noqa: F401
