from repro.serving.engine import Engine, GenStats  # noqa: F401
from repro.serving.spec_decode import greedy_accept, SpecResult  # noqa: F401
from repro.serving import sampler  # noqa: F401
