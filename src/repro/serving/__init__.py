from repro.serving.engine import Engine, GenStats  # noqa: F401
from repro.serving.errors import (  # noqa: F401
    DeadlineExceeded, DeadlineUnmeetable, InvalidRequest,
    InvariantViolation, QueueFull, RequestCancelled, RequestFailed,
    ServingError, ShuttingDown, TransientFault, WatchdogTimeout,
    error_for_reason,
)
from repro.serving.faults import (  # noqa: F401
    Fault, FaultInjector, InjectedFault, SimulatedCrash, sample_campaign,
)
from repro.serving.frontdoor import (  # noqa: F401
    FrontDoor, RecoveryReport, TokenStream, recover,
)
from repro.serving.journal import (  # noqa: F401
    JournalTail, JournalWriter, Snapshot, fold_records, load_snapshot,
    read_journal, save_snapshot,
)
from repro.serving.scheduler import (  # noqa: F401
    Request, RequestState, Scheduler, tighten_policy,
)
from repro.serving.spec_scheduler import (  # noqa: F401
    SpecConfig, SpecScheduler,
)
from repro.serving.step import (  # noqa: F401
    SpecStepFns, StepFns, build_spec_fns, build_step_fns,
    decode_steps_fused, gate_probe, make_fused, make_spec_fused,
    spec_steps_fused,
)
from repro.serving.spec_decode import (  # noqa: F401
    greedy_accept, rollback_cur_len, SpecResult,
)
from repro.serving import errors, faults, sampler  # noqa: F401
