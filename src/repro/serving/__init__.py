from repro.serving.engine import Engine, GenStats  # noqa: F401
from repro.serving.scheduler import (  # noqa: F401
    Request, RequestState, Scheduler,
)
from repro.serving.step import (  # noqa: F401
    StepFns, build_step_fns, decode_steps_fused, gate_probe,
)
from repro.serving.spec_decode import (  # noqa: F401
    greedy_accept, rollback_cur_len, SpecResult,
)
from repro.serving import sampler  # noqa: F401
