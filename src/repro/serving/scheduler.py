"""Continuous-batching scheduler with XShare-aware admission and a
serving robustness layer.

The serving substrate the paper's batch-composition premise actually
needs: requests arrive and finish at different times, and the scheduler
keeps a fixed-size running batch (static shapes for jit) whose slots
have independent lifetimes.

Request lifecycle:  waiting -> prefill -> decode -> done | shed.

  * waiting  — submitted; not yet visible (future arrival) or queued.
  * prefill  — a single-request prefill builds its cache row, the first
               token is sampled from the prefill logits, and the row is
               spliced into the running batch cache (insert_request).
  * decode   — the slot participates in fused N-token decode scans
               (serving/step.py); the scheduler harvests tokens between
               scans.
  * done     — reached max_new_tokens; the slot is evicted and refilled
               from the queue.
  * shed     — any non-success terminal state (cancelled, deadline
               expiry, admission shed, numerics quarantine, fault);
               ``finish_reason`` (serving/errors.py) says which.

Admission policies:

  * "fcfs"     — first come, first served.
  * "affinity" — the paper's correlation-aware selection lifted to the
                 scheduling layer: each request carries a gate histogram
                 (cheap router probe at submit time); admission greedily
                 picks the waiting request whose histogram maximally
                 overlaps the running batch's aggregated gate mass
                 (core/selection.py rank_by_affinity). Batches then
                 share experts *by construction*, shrinking the
                 activated set every XShare policy works against.

Robustness layer (all opt-in, zero-cost when off):

  * deadlines  — per-request TTFT and end-to-end budgets; expired
                 queued requests are shed before they stall admission,
                 expired running requests are evicted mid-decode.
  * cancel(rid) — abort a queued or mid-decode request; its slot is
                 evicted and refilled on the next admission pass.
  * bounded queue — ``max_queue`` depth plus an estimated-wait budget
                 (``admit_wait_budget_s`` against an observed-throughput
                 EMA); over budget either raises (overload="reject") or
                 sheds with a structured reason (overload="shed").
  * graceful degradation — a pressure ladder (queue depth / slots, and
                 watchdog stalls): each level falls back from affinity
                 to FCFS admission and tightens the XShare
                 policy_max_active budget (tighten_policy below), so
                 throughput degrades smoothly under load and recovers
                 with hysteresis when pressure clears.
  * numerics quarantine — the fused scan flags slots whose logits went
                 non-finite; only that request is terminated (evicted
                 with a scrubbed cache row), the rest of the batch is
                 bit-exact with a fault-free run.
  * watchdog   — per-step wall-time budget (``watchdog_s``) counts
                 stalls into the pressure signal; transient step faults
                 (serving/faults.py) are retried with exponential
                 backoff before the request is shed.
  * invariants — ``check_invariants()`` validates the slot-state
                 machine, cur_len ↔ active-mask consistency, and
                 batch-mass accounting after every scheduler
                 intervention when ``invariants=True``.
"""
from __future__ import annotations

import dataclasses
import time
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig, MoEConfig, XSharePolicy
from repro.core.selection import rank_by_affinity
from repro.models import init_cache
from repro.models.model import effective_window
from repro.models.moe import OFF
from repro.serving.errors import (REASON_CANCELLED, REASON_COMPLETED,
                                  REASON_DEADLINE_E2E, REASON_DEADLINE_TTFT,
                                  REASON_FAULT, REASON_NUMERICS,
                                  REASON_SHED_QUEUE, REASON_SHED_WAIT,
                                  REASON_WALL, DeadlineUnmeetable,
                                  InvariantViolation, QueueFull,
                                  TransientFault, WatchdogTimeout,
                                  validate_request)
from repro.serving.faults import FaultInjector
from repro.serving.sampler import sample_step
from repro.serving.step import NO_FAULT, StepFns, build_step_fns, make_fused

WAITING, PREFILL, DECODE, DONE, SHED = \
    "waiting", "prefill", "decode", "done", "shed"

# legal slot-state machine edges (enforced by _set_status / invariants)
_TRANSITIONS = {
    WAITING: (PREFILL, SHED),
    PREFILL: (DECODE, DONE, SHED),
    DECODE: (DONE, SHED),
    DONE: (),
    SHED: (),
}

MAX_DEGRADE = 2  # degradation-ladder depth (level 0 = healthy)


def tighten_policy(policy: XSharePolicy, level: int,
                   moe: Optional[MoEConfig]) -> XSharePolicy:
    """Degradation ladder for the XShare budget: each level halves the
    policy's headroom so policy_max_active — and with it the sorted
    dispatch's padded layout and expert weight traffic — shrinks under
    load. An OFF policy gains a batch budget (there is nothing to
    tighten otherwise); floors keep at least top_k-ish experts live so
    routing never degenerates to an empty set."""
    if level <= 0 or moe is None:
        return policy
    if policy.mode == "off":
        m = max(moe.top_k, moe.num_experts >> (level + 1))
        return XSharePolicy(mode="batch", k0=1, m_l=m)
    if policy.mode == "batch":
        return dataclasses.replace(policy, m_l=policy.m_l >> level)
    if policy.mode == "ep":
        return dataclasses.replace(policy, m_g=max(1, policy.m_g >> level))
    if policy.mode == "spec":
        return dataclasses.replace(policy, m_l=policy.m_l >> level,
                                   m_r=max(1, policy.m_r >> level))
    return policy


@dataclass
class Request:
    """One generation request. prompt: (S,) int32 ((S, K) audio).
    deadline_s / ttft_deadline_s are budgets relative to arrival_s."""
    rid: int
    prompt: np.ndarray
    max_new_tokens: int
    arrival_s: float = 0.0  # relative to Scheduler.run() start
    deadline_s: Optional[float] = None
    ttft_deadline_s: Optional[float] = None
    spec: bool = False      # decode speculatively (SpecScheduler only)


@dataclass
class RequestState:
    """Lifecycle + per-request accounting (stats tagged per request)."""
    req: Request
    status: str = WAITING
    slot: int = -1
    tokens: List = field(default_factory=list)
    gate_hist: Optional[np.ndarray] = None
    finish_reason: Optional[str] = None
    cancel_requested: bool = False
    history: List[str] = field(default_factory=lambda: [WAITING])
    mass_counted: bool = False   # gate_hist currently in _batch_mass
    t_admitted: float = float("nan")
    t_first_token: float = float("nan")
    t_done: float = float("nan")
    # batch-level XShare aux for every fused step this request was live in
    layer_aux: List[Dict] = field(default_factory=list)
    # speculative-decoding accounting (SpecScheduler)
    drafted: int = 0             # draft tokens proposed for this request
    accepted_drafts: int = 0     # draft tokens the target accepted
    spec_budget_exhausted: bool = False

    @property
    def latency_s(self) -> float:
        return self.t_done - self.req.arrival_s

    @property
    def ttft_s(self) -> float:
        return self.t_first_token - self.req.arrival_s


class Scheduler:
    """Continuous-batching scheduler over a fixed-size slot array.

    Drives the compiled StepFns bundle: per-request prefill + cache
    insert on admission, fused N-token decode scans over the running
    batch, eviction + re-admission as requests finish. The robustness
    knobs (see module docstring) all default off, leaving the healthy
    path bit-identical to the plain scheduler.
    """

    def __init__(self, cfg: ArchConfig, params, *,
                 num_slots: int,
                 cache_len: int = 512,
                 policy: XSharePolicy = OFF,
                 admission: str = "fcfs",
                 decode_chunk: int = 8,
                 temperature: float = 0.0,
                 force_window: Optional[int] = None,
                 capacity_factor: float = 8.0,
                 dispatch: str = "auto",
                 seed: int = 0,
                 fns: Optional[StepFns] = None,
                 max_queue: Optional[int] = None,
                 overload: str = "reject",
                 admit_wait_budget_s: Optional[float] = None,
                 watchdog_s: Optional[float] = None,
                 max_retries: int = 2,
                 retry_backoff_s: float = 0.02,
                 degrade: bool = False,
                 degrade_hi: float = 2.0,
                 degrade_lo: float = 0.5,
                 invariants: bool = False,
                 faults: Optional[FaultInjector] = None,
                 on_round: Optional[Callable] = None,
                 fused_cache: Optional[Dict[int, Callable]] = None):
        if admission not in ("fcfs", "affinity"):
            raise ValueError(f"unknown admission policy {admission!r}")
        if overload not in ("reject", "shed"):
            raise ValueError(f"unknown overload policy {overload!r}")
        self.cfg, self.params = cfg, params
        self.num_slots = num_slots
        self.cache_len = cache_len
        self.admission = admission
        self.temperature = temperature
        self.policy = policy
        self.max_queue = max_queue
        self.overload = overload
        self.admit_wait_budget_s = admit_wait_budget_s
        self.watchdog_s = watchdog_s
        self.max_retries = max_retries
        self.retry_backoff_s = retry_backoff_s
        self.degrade = degrade
        self.degrade_hi = degrade_hi
        self.degrade_lo = degrade_lo
        self.invariants = invariants
        self.faults = faults
        self.on_round = on_round
        self._force_window = force_window
        self._capacity_factor = capacity_factor
        self._dispatch = dispatch
        self._window = effective_window(cfg, force_window=force_window)
        self.fns = fns or build_step_fns(
            cfg, policy=policy, cache_len=cache_len,
            decode_chunk=decode_chunk, temperature=temperature,
            force_window=force_window, capacity_factor=capacity_factor,
            dispatch=dispatch)
        self._key = jax.random.PRNGKey(seed)
        self._next_rid = 0
        self._incoming: List[RequestState] = []   # not yet arrived
        self._queue: List[RequestState] = []      # arrived, waiting
        self._slots: List[Optional[RequestState]] = [None] * num_slots
        self._states: List[RequestState] = []     # submission order
        self._by_rid: Dict[int, RequestState] = {}
        # device-side running-batch state
        dtype = jax.tree_util.tree_leaves(params)[0].dtype
        self._cache = init_cache(cfg, num_slots, cache_len, dtype,
                                 force_window=force_window)
        tok_shape = (num_slots,) if cfg.num_codebooks == 1 \
            else (num_slots, cfg.num_codebooks)
        self._tok = jnp.zeros(tok_shape, jnp.int32)
        self._active = np.zeros(num_slots, bool)
        # host-side aggregated gate mass of the running batch (affinity)
        E = cfg.moe.num_experts if cfg.moe else 0
        self._batch_mass = np.zeros(E, np.float64)
        self.total_steps = 0          # fused decode steps executed
        self.step_aux: List[Dict] = []  # batch-level aux per decode step
        self._t0: Optional[float] = None
        self.wall_s = 0.0             # frozen at the end of run()
        # robustness accounting
        self.level = 0                                # degradation level
        self.degrade_events: List = []                # (t, new level)
        self.stall_events = 0                         # watchdog overruns
        self.retries = 0                              # transient retries
        self._stalls_acked = 0
        self._round_idx = 0
        self._otps_ema: Optional[float] = None
        # degradation-level fused scans; an engine-shared dict
        # (fused_cache) lets every scheduler of one engine reuse the
        # tightened-policy compiles instead of paying them per serve
        self._fused_levels: Dict[int, Callable] = \
            fused_cache if fused_cache is not None else {}
        self._fused_levels.setdefault(0, self.fns.fused)

    def _resolve_spec(self, spec: Optional[bool]) -> bool:
        """Plain scheduler: speculative requests are not supported —
        spec=None/False is accepted (and means plain decode) so callers
        can use one submit signature; spec=True is a caller error.
        SpecScheduler overrides this with its own default."""
        if spec:
            raise ValueError(
                "spec=True needs a SpecScheduler (engine draft model + "
                "spec_len > 0)")
        return False

    # ------------------------------------------------------------- time --

    def _now(self) -> float:
        return time.perf_counter() - self._t0 if self._t0 is not None \
            else 0.0

    # -------------------------------------------------------- submission --

    def submit(self, prompt: np.ndarray, max_new_tokens: int, *,
               arrival_s: float = 0.0,
               deadline_s: Optional[float] = None,
               ttft_deadline_s: Optional[float] = None,
               spec: Optional[bool] = None) -> RequestState:
        prompt = np.asarray(prompt)
        validate_request(int(prompt.shape[0]) if prompt.ndim else 0,
                         max_new_tokens, cache_len=self.cache_len,
                         window=self._window)
        req = Request(rid=self._next_rid, prompt=prompt,
                      max_new_tokens=max_new_tokens, arrival_s=arrival_s,
                      deadline_s=deadline_s,
                      ttft_deadline_s=ttft_deadline_s,
                      spec=self._resolve_spec(spec))
        self._next_rid += 1
        st = RequestState(req=req)
        # --- bounded-queue admission control -----------------------------
        pending = len(self._incoming) + len(self._queue)
        if self.max_queue is not None and pending >= self.max_queue:
            return self._refuse(st, REASON_SHED_QUEUE, QueueFull(
                f"queue at capacity ({pending}/{self.max_queue})"))
        est = self._estimated_wait_s()
        if (self.admit_wait_budget_s is not None and est is not None
                and est > self.admit_wait_budget_s):
            return self._refuse(st, REASON_SHED_WAIT, DeadlineUnmeetable(
                f"estimated wait {est:.3f}s exceeds admission budget "
                f"{self.admit_wait_budget_s:.3f}s"))
        if self.admission == "affinity" and self.fns.probe is not None:
            hist = self.fns.probe(self.params, req.prompt[None])
            st.gate_hist = np.asarray(hist, np.float64)
        self._states.append(st)
        self._by_rid[req.rid] = st
        self._incoming.append(st)
        return st

    def _refuse(self, st: RequestState, reason: str, exc: Exception):
        """Admission control refusal: raise (overload="reject") or
        record the request as shed (overload="shed")."""
        if self.overload == "reject":
            raise exc
        self._states.append(st)
        self._by_rid[st.req.rid] = st
        self._finish(st, slot=None, reason=reason)
        return st

    def _estimated_wait_s(self) -> Optional[float]:
        """Outstanding token debt over the observed throughput EMA —
        None until the first decode round calibrates the rate."""
        if not self._otps_ema:
            return None
        owed = sum(s.req.max_new_tokens - len(s.tokens)
                   for s in self._queue)
        owed += sum(s.req.max_new_tokens - len(s.tokens)
                    for s in self._slots if s is not None)
        return owed / self._otps_ema

    # ------------------------------------------------------ cancellation --

    def cancel(self, rid: int) -> bool:
        """Abort a request: queued requests leave the queue immediately;
        a mid-decode request's slot is evicted on the spot (the
        scheduler is single-threaded — callers reach this between fused
        rounds, e.g. from the on_round hook). Returns False if the
        request is unknown or already terminal."""
        st = self._by_rid.get(rid)
        if st is None or st.status in (DONE, SHED):
            return False
        st.cancel_requested = True
        if st.status == WAITING:
            if st in self._incoming:
                self._incoming.remove(st)
            if st in self._queue:
                self._queue.remove(st)
            self._finish(st, slot=None, reason=REASON_CANCELLED)
        elif st.slot >= 0:
            self._finish(st, slot=st.slot, reason=REASON_CANCELLED)
        return True

    # --------------------------------------------------------- lifecycle --

    def _set_status(self, st: RequestState, new: str) -> None:
        if new not in _TRANSITIONS[st.status]:
            raise InvariantViolation(
                f"illegal transition {st.status} -> {new} "
                f"(rid {st.req.rid}, history {st.history})")
        st.status = new
        st.history.append(new)

    # ----------------------------------------------------- expert priors --

    def gate_priors(self) -> np.ndarray:
        """Per-slot expert-affinity priors of the running batch:
        (num_slots, E) float64, row s = slot s's best current gate-
        histogram estimate (zeros for empty slots; E == 0 columns for
        router-free models). The stable read API for expert-affinity
        consumers — EP placement feeds the batch-aggregate
        ``gate_priors().sum(0)`` into ``ep.plan_placement`` /
        ``EPExecutor.update_placement``, and SpecScheduler's override
        supplies Algorithm-4's correlation priors — instead of each
        consumer poking at slot internals (``_slots[s].gate_hist``,
        ``_slot_spec[s].prior``).

        Base scheduler: the admission-time prompt gate histograms
        (``RequestState.gate_hist``), static per request.
        """
        E = self.cfg.moe.num_experts if self.cfg.moe else 0
        out = np.zeros((self.num_slots, E), np.float64)
        if E:
            for s, st in enumerate(self._slots):
                if st is not None and st.gate_hist is not None:
                    out[s] = st.gate_hist
        return out

    # --------------------------------------------------------- admission --

    @property
    def admission_effective(self) -> str:
        """Degradation ladder level >= 1 falls back to FCFS (skips the
        affinity ranking work and its batch-composition constraint)."""
        return "fcfs" if self.level > 0 else self.admission

    def _pick_next(self) -> RequestState:
        """Greedy XShare-aware admission: the queued request whose gate
        histogram maximally overlaps the running batch's aggregated gate
        mass. FIFO when configured so, when the model has no router, or
        when the batch is empty (all scores 0, argmax -> head)."""
        if self.admission_effective == "fcfs" or not len(self._batch_mass) \
                or any(s.gate_hist is None for s in self._queue):
            return self._queue.pop(0)
        hists = np.stack([s.gate_hist for s in self._queue])
        scores = np.asarray(rank_by_affinity(
            jnp.asarray(hists), jnp.asarray(self._batch_mass)))
        return self._queue.pop(int(scores.argmax()))

    def _first_token(self, logits: jnp.ndarray) -> jnp.ndarray:
        self._key, k = jax.random.split(self._key)
        return sample_step(logits, k, temperature=self.temperature)

    def _retry(self, what: str, rid: int, call: Callable):
        """Watchdog retry loop: transient faults (injected or wrapped)
        back off exponentially; exhaustion raises WatchdogTimeout and
        the caller sheds just that request."""
        delay = self.retry_backoff_s
        for attempt in range(self.max_retries + 1):
            try:
                if self.faults is not None and what == "insert":
                    self.faults.before_insert(rid)
                return call()
            except TransientFault as e:
                self.retries += 1
                if attempt == self.max_retries:
                    raise WatchdogTimeout(
                        f"{what} rid={rid} failed after "
                        f"{self.max_retries + 1} attempts: {e}") from e
                time.sleep(delay)
                delay *= 2

    def _admit_group(self, group, now: float) -> None:
        """Prefill a group of same-shape admissions as ONE batched
        prefill and splice each row into its slot. Simultaneous arrivals
        (the all-at-t=0 case) therefore pay a single prefill dispatch —
        and run through the numerically identical computation the
        lockstep engine's batched prefill performs."""
        t_pre = time.perf_counter()   # watchdog window includes host stalls
        if self.faults is not None:
            self.faults.before_prefill([st.req.rid for st, _ in group])
        prompts = np.stack([st.req.prompt for st, _ in group])
        lg, req_cache, _ = self.fns.prefill(self.params, prompts)
        toks0 = self._first_token(lg)              # (G,) or (G, K)
        toks0_np = np.asarray(toks0)   # blocks: TTFT must include device time
        if self.watchdog_s is not None and \
                time.perf_counter() - t_pre > self.watchdog_s:
            self.stall_events += 1
        t_first = time.perf_counter() - self._t0
        if (len(group) == self.num_slots
                and [slot for _, slot in group] == list(range(len(group)))
                and not self._active.any()
                and all(st.req.max_new_tokens > 1 for st, _ in group)):
            # whole-batch admission into an empty machine (the all-at-t=0
            # case): the group prefill cache IS the running cache — skip
            # the per-slot insert dispatches entirely
            self._cache = req_cache
            self._tok = toks0
            for i, (st, slot) in enumerate(group):
                self._set_status(st, PREFILL)
                self._set_status(st, DECODE)
                st.t_admitted = now
                st.tokens.append(toks0_np[i])
                st.t_first_token = t_first
                st.slot = slot
                self._slots[slot] = st
                self._active[slot] = True
            return
        for i, (st, slot) in enumerate(group):
            self._set_status(st, PREFILL)
            st.t_admitted = now
            st.tokens.append(toks0_np[i])
            st.t_first_token = t_first
            if len(st.tokens) >= st.req.max_new_tokens:
                self._finish(st, slot=None)
                continue
            try:
                self._cache = self._retry(
                    "insert", st.req.rid,
                    lambda: self.fns.insert(
                        self._cache, req_cache, jnp.asarray(slot, jnp.int32),
                        jnp.asarray(i, jnp.int32)))
            except WatchdogTimeout:
                # the splice itself is the casualty: shed this request,
                # leave the slot free for the next admission pass
                self._finish(st, slot=None, reason=REASON_FAULT)
                continue
            self._tok = self._tok.at[slot].set(toks0[i])
            self._slots[slot] = st
            self._active[slot] = True
            st.slot = slot
            self._set_status(st, DECODE)

    def _finish(self, st: RequestState, slot: Optional[int],
                reason: str = REASON_COMPLETED, scrub: bool = False) -> None:
        self._set_status(st, DONE if reason == REASON_COMPLETED else SHED)
        st.finish_reason = reason
        st.t_done = self._now()
        if st.mass_counted and st.gate_hist is not None:
            self._batch_mass -= st.gate_hist
            st.mass_counted = False
        if slot is not None:
            evict = self.fns.evict_scrub if scrub else self.fns.evict
            self._cache = evict(self._cache, jnp.asarray(slot, jnp.int32))
            self._slots[slot] = None
            self._active[slot] = False
            st.slot = -1

    def _fill_slots(self, now: float) -> None:
        # shed queued requests that can no longer meet their deadline —
        # BEFORE they occupy a slot, so expiry never stalls admission
        still = []
        for st in self._queue:
            r = st.req
            if st.cancel_requested:
                self._finish(st, slot=None, reason=REASON_CANCELLED)
            elif r.ttft_deadline_s is not None and \
                    now > r.arrival_s + r.ttft_deadline_s:
                self._finish(st, slot=None, reason=REASON_DEADLINE_TTFT)
            elif r.deadline_s is not None and \
                    now > r.arrival_s + r.deadline_s:
                self._finish(st, slot=None, reason=REASON_DEADLINE_E2E)
            else:
                still.append(st)
        self._queue[:] = still
        free = [s for s in range(self.num_slots) if self._slots[s] is None]
        picks = []
        while free and self._queue:
            st = self._pick_next()         # greedy: sees mass so far
            if st.gate_hist is not None:
                self._batch_mass += st.gate_hist
                st.mass_counted = True
            picks.append((st, free.pop(0)))
        # batch same-shape prompts into one prefill dispatch
        by_shape: Dict = {}
        for st, slot in picks:
            by_shape.setdefault(st.req.prompt.shape, []).append((st, slot))
        for group in by_shape.values():
            self._admit_group(group, now)

    # ------------------------------------------------------------ decode --

    def _fused_at(self, level: int) -> Callable:
        """The fused scan for a degradation level — level 0 is the
        configured bundle; higher levels lazily compile a variant with
        a tightened XShare policy (everything else identical)."""
        if level == 0 or self.cfg.moe is None:
            return self.fns.fused
        if level not in self._fused_levels:
            pol = tighten_policy(self.policy, level, self.cfg.moe)
            self._fused_levels[level] = make_fused(
                self.cfg, policy=pol, decode_chunk=self.fns.decode_chunk,
                temperature=self.temperature,
                force_window=self._force_window,
                capacity_factor=self._capacity_factor,
                dispatch=self._dispatch)
        return self._fused_levels[level]

    def _decode_round(self) -> None:
        """One fused N-token scan + harvest. Slots carry their remaining
        token budget on device, so a request that finishes mid-chunk
        stops computing (and influencing XShare selection) on the next
        step, not at the chunk boundary. Poisoned slots (non-finite
        logits) are quarantined: their request is shed and the slot
        evicted with a scrubbed cache row; the co-batched slots'
        tokens are bit-exact with a fault-free round."""
        t_round = time.perf_counter()
        chunk = self.fns.decode_chunk
        if self.faults is not None:
            self.faults.before_round(self._round_idx)
            fault = self.faults.nan_fault(self.total_steps,
                                          self.total_steps + chunk)
        else:
            fault = NO_FAULT
        remaining = np.asarray(
            [st.req.max_new_tokens - len(st.tokens) if st else 0
             for st in self._slots], np.int32)
        self._key, k = jax.random.split(self._key)
        self._tok, self._cache, toks, aux, ok, poisoned = \
            self._fused_at(self.level)(
                self.params, self._tok, self._cache,
                jnp.asarray(remaining), k,
                jnp.asarray(fault, jnp.int32))
        toks = np.asarray(toks)                    # sync point: (N, B[,K])
        ok = np.asarray(ok)                        # (N, B)
        poisoned = np.asarray(poisoned)            # (B,)
        dt = time.perf_counter() - t_round
        if self.watchdog_s is not None and dt > self.watchdog_s:
            self.stall_events += 1
        now = self._now()
        N = toks.shape[0]
        self.total_steps += N
        self._round_idx += 1
        aux_np = {kk: np.asarray(v) for kk, v in aux.items()}
        step_auxs = [{kk: v[i] for kk, v in aux_np.items()}
                     for i in range(N)]
        self.step_aux.extend(step_auxs)
        valid = ok.sum(axis=0)                     # real tokens per slot
        for slot, st in enumerate(self._slots):
            if st is None:
                continue
            take = min(int(valid[slot]),
                       st.req.max_new_tokens - len(st.tokens))
            st.tokens.extend(toks[i, slot] for i in range(take))
            st.layer_aux.extend(step_auxs[:take])
            if poisoned[slot]:
                self._finish(st, slot=slot, reason=REASON_NUMERICS,
                             scrub=True)
            elif len(st.tokens) >= st.req.max_new_tokens:
                self._finish(st, slot=slot)
        harvested = int(valid.sum())
        if harvested and dt > 0:
            rate = harvested / dt
            self._otps_ema = rate if self._otps_ema is None \
                else 0.5 * self._otps_ema + 0.5 * rate
        # end-to-end deadlines for still-running requests
        for slot, st in enumerate(self._slots):
            if st is not None and st.req.deadline_s is not None and \
                    now > st.req.arrival_s + st.req.deadline_s:
                self._finish(st, slot=slot, reason=REASON_DEADLINE_E2E)
        if self.on_round is not None:
            self.on_round(self, self._round_idx)

    # -------------------------------------------------------- degradation --

    def _update_degradation(self, now: float) -> None:
        """Pressure ladder with hysteresis: queue depth per slot (and
        fresh watchdog stalls) escalate one level; calm recovers one."""
        if not self.degrade:
            return
        new_stalls = self.stall_events - self._stalls_acked
        self._stalls_acked = self.stall_events
        p = len(self._queue) / max(1, self.num_slots)
        lvl = self.level
        if (p >= self.degrade_hi or new_stalls) and lvl < MAX_DEGRADE:
            lvl += 1
        elif p <= self.degrade_lo and not new_stalls and lvl > 0:
            lvl -= 1
        if lvl != self.level:
            self.level = lvl
            self.degrade_events.append((now, lvl))

    # --------------------------------------------------------------- run --

    def _shed_all(self, reason: str) -> None:
        """Terminal sweep: everything not yet finished is shed."""
        for st in list(self._incoming) + list(self._queue):
            self._finish(st, slot=None, reason=reason)
        self._incoming.clear()
        self._queue.clear()
        for slot, st in enumerate(self._slots):
            if st is not None:
                self._finish(st, slot=slot, reason=reason)

    def run(self, *, max_wall_s: Optional[float] = None,
            keep_alive: Optional[Callable[[], bool]] = None
            ) -> List[RequestState]:
        """Serve every submitted request to a terminal state. Arrival
        times are honored against the wall clock (arrival_s is relative
        to this call). max_wall_s bounds the serve loop: on expiry every
        unfinished request is shed (reason "run_wall_timeout") so run()
        is guaranteed to return even under a fault campaign. Returns
        RequestStates in submission order.

        keep_alive — the front door's pump (serving/frontdoor.py):
        called once per loop iteration BEFORE admission, it may submit
        or cancel requests (the scheduler is single-threaded; this is
        the one sanctioned re-entry point alongside on_round) and its
        return value keeps the loop alive while True even with nothing
        queued or running, so an open door can idle-wait for traffic.
        Without it the loop exits exactly as before — when all
        submitted work is terminal."""
        self._t0 = time.perf_counter()
        self.wall_s = 0.0
        self._incoming.sort(key=lambda s: s.req.arrival_s)
        while True:
            alive = bool(keep_alive()) if keep_alive is not None else False
            if not (self._incoming or self._queue or self._active.any()
                    or alive):
                break
            now = self._now()
            if max_wall_s is not None and now > max_wall_s:
                self._shed_all(REASON_WALL)
                break
            if self._incoming:
                # keep_alive() may have appended out of arrival order;
                # promote every due request (a filter preserves the
                # sorted order of the initial batch)
                due = [s for s in self._incoming if s.req.arrival_s <= now]
                if due:
                    self._incoming = [s for s in self._incoming
                                      if s.req.arrival_s > now]
                    self._queue.extend(due)
            self._update_degradation(now)
            self._fill_slots(now)
            if self._active.any():
                self._decode_round()
            elif self._incoming:
                time.sleep(min(0.01, max(0.0, min(
                    s.req.arrival_s for s in self._incoming) - now)))
            elif alive:
                time.sleep(0.001)     # open door, no traffic: idle poll
            if self.invariants:
                self.check_invariants()
        self.wall_s = time.perf_counter() - self._t0
        return self._states

    @property
    def elapsed_s(self) -> float:
        """Serve wall clock: live while run() is in flight, frozen at its
        end, 0.0 before the first run()."""
        if self._t0 is None:
            return 0.0
        return self.wall_s or (time.perf_counter() - self._t0)

    # --------------------------------------------------------- reporting --

    def reason_counts(self) -> Dict[str, int]:
        """Terminal-state census: finish_reason -> count."""
        out: Dict[str, int] = {}
        for st in self._states:
            if st.finish_reason is not None:
                out[st.finish_reason] = out.get(st.finish_reason, 0) + 1
        return out

    # -------------------------------------------------------- invariants --

    def check_invariants(self) -> None:
        """Slot-state machine, cur_len ↔ active-mask consistency, and
        batch-mass accounting. Raises InvariantViolation on the first
        breach; cheap enough to run after every scheduler intervention
        under tests and fault campaigns (one device sync per call)."""
        cur = np.asarray(self._cache["cur_len"])
        mass = np.zeros_like(self._batch_mass)
        for s in range(self.num_slots):
            st = self._slots[s]
            if st is None:
                if self._active[s]:
                    raise InvariantViolation(f"empty slot {s} marked active")
                if cur[s] != 0:
                    raise InvariantViolation(
                        f"empty slot {s} has cur_len {cur[s]} != 0")
                continue
            if not self._active[s]:
                raise InvariantViolation(
                    f"occupied slot {s} (rid {st.req.rid}) inactive")
            if st.status != DECODE or st.slot != s:
                raise InvariantViolation(
                    f"slot {s}: status {st.status!r} slot-field {st.slot}")
            expect = int(st.req.prompt.shape[0]) + len(st.tokens) - 1
            if cur[s] != expect:
                raise InvariantViolation(
                    f"slot {s} (rid {st.req.rid}): cur_len {cur[s]} != "
                    f"prompt+tokens-1 = {expect}")
            if st.mass_counted and st.gate_hist is not None:
                mass += st.gate_hist
        if len(mass) and not np.allclose(mass, self._batch_mass, atol=1e-6):
            raise InvariantViolation(
                f"batch gate-mass drift: |Δ|={np.abs(mass - self._batch_mass).max()}")
        for st in self._states:
            for a, b in zip(st.history, st.history[1:]):
                if b not in _TRANSITIONS[a]:
                    raise InvariantViolation(
                        f"rid {st.req.rid}: illegal recorded transition "
                        f"{a} -> {b} in {st.history}")
            if st.status in (DONE, SHED) and st.finish_reason is None:
                raise InvariantViolation(
                    f"rid {st.req.rid}: terminal without finish_reason")
