"""Continuous-batching scheduler with XShare-aware admission.

The serving substrate the paper's batch-composition premise actually
needs: requests arrive and finish at different times, and the scheduler
keeps a fixed-size running batch (static shapes for jit) whose slots
have independent lifetimes.

Request lifecycle:  waiting -> prefill -> decode -> done.

  * waiting  — submitted; not yet visible (future arrival) or queued.
  * prefill  — a single-request prefill builds its cache row, the first
               token is sampled from the prefill logits, and the row is
               spliced into the running batch cache (insert_request).
  * decode   — the slot participates in fused N-token decode scans
               (serving/step.py); the scheduler harvests tokens between
               scans.
  * done     — reached max_new_tokens; the slot is evicted and refilled
               from the queue.

Admission policies:

  * "fcfs"     — first come, first served.
  * "affinity" — the paper's correlation-aware selection lifted to the
                 scheduling layer: each request carries a gate histogram
                 (cheap router probe at submit time); admission greedily
                 picks the waiting request whose histogram maximally
                 overlaps the running batch's aggregated gate mass
                 (core/selection.py rank_by_affinity). Batches then
                 share experts *by construction*, shrinking the
                 activated set every XShare policy works against.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig, XSharePolicy
from repro.core.selection import rank_by_affinity
from repro.models import init_cache
from repro.models.moe import OFF
from repro.serving.sampler import sample_step
from repro.serving.step import StepFns, build_step_fns

WAITING, PREFILL, DECODE, DONE = "waiting", "prefill", "decode", "done"


@dataclass
class Request:
    """One generation request. prompt: (S,) int32 ((S, K) audio)."""
    rid: int
    prompt: np.ndarray
    max_new_tokens: int
    arrival_s: float = 0.0  # relative to Scheduler.run() start


@dataclass
class RequestState:
    """Lifecycle + per-request accounting (stats tagged per request)."""
    req: Request
    status: str = WAITING
    slot: int = -1
    tokens: List = field(default_factory=list)
    gate_hist: Optional[np.ndarray] = None
    t_admitted: float = float("nan")
    t_first_token: float = float("nan")
    t_done: float = float("nan")
    # batch-level XShare aux for every fused step this request was live in
    layer_aux: List[Dict] = field(default_factory=list)

    @property
    def latency_s(self) -> float:
        return self.t_done - self.req.arrival_s

    @property
    def ttft_s(self) -> float:
        return self.t_first_token - self.req.arrival_s


class Scheduler:
    """Continuous-batching scheduler over a fixed-size slot array.

    Drives the compiled StepFns bundle: per-request prefill + cache
    insert on admission, fused N-token decode scans over the running
    batch, eviction + re-admission as requests finish.
    """

    def __init__(self, cfg: ArchConfig, params, *,
                 num_slots: int,
                 cache_len: int = 512,
                 policy: XSharePolicy = OFF,
                 admission: str = "fcfs",
                 decode_chunk: int = 8,
                 temperature: float = 0.0,
                 force_window: Optional[int] = None,
                 capacity_factor: float = 8.0,
                 dispatch: str = "auto",
                 seed: int = 0,
                 fns: Optional[StepFns] = None):
        if admission not in ("fcfs", "affinity"):
            raise ValueError(f"unknown admission policy {admission!r}")
        self.cfg, self.params = cfg, params
        self.num_slots = num_slots
        self.cache_len = cache_len
        self.admission = admission
        self.temperature = temperature
        self.fns = fns or build_step_fns(
            cfg, policy=policy, cache_len=cache_len,
            decode_chunk=decode_chunk, temperature=temperature,
            force_window=force_window, capacity_factor=capacity_factor,
            dispatch=dispatch)
        self._key = jax.random.PRNGKey(seed)
        self._next_rid = 0
        self._incoming: List[RequestState] = []   # not yet arrived
        self._queue: List[RequestState] = []      # arrived, waiting
        self._slots: List[Optional[RequestState]] = [None] * num_slots
        self._states: List[RequestState] = []     # submission order
        # device-side running-batch state
        dtype = jax.tree_util.tree_leaves(params)[0].dtype
        self._cache = init_cache(cfg, num_slots, cache_len, dtype,
                                 force_window=force_window)
        tok_shape = (num_slots,) if cfg.num_codebooks == 1 \
            else (num_slots, cfg.num_codebooks)
        self._tok = jnp.zeros(tok_shape, jnp.int32)
        self._active = np.zeros(num_slots, bool)
        # host-side aggregated gate mass of the running batch (affinity)
        E = cfg.moe.num_experts if cfg.moe else 0
        self._batch_mass = np.zeros(E, np.float64)
        self.total_steps = 0          # fused decode steps executed
        self.step_aux: List[Dict] = []  # batch-level aux per decode step
        self._t0: Optional[float] = None
        self.wall_s = 0.0             # frozen at the end of run()

    # -------------------------------------------------------- submission --

    def submit(self, prompt: np.ndarray, max_new_tokens: int, *,
               arrival_s: float = 0.0) -> RequestState:
        req = Request(rid=self._next_rid, prompt=np.asarray(prompt),
                      max_new_tokens=max_new_tokens, arrival_s=arrival_s)
        self._next_rid += 1
        st = RequestState(req=req)
        if self.admission == "affinity" and self.fns.probe is not None:
            hist = self.fns.probe(self.params, req.prompt[None])
            st.gate_hist = np.asarray(hist, np.float64)
        self._states.append(st)
        self._incoming.append(st)
        return st

    # --------------------------------------------------------- admission --

    def _pick_next(self) -> RequestState:
        """Greedy XShare-aware admission: the queued request whose gate
        histogram maximally overlaps the running batch's aggregated gate
        mass. FIFO when configured so, when the model has no router, or
        when the batch is empty (all scores 0, argmax -> head)."""
        if self.admission == "fcfs" or not len(self._batch_mass) \
                or any(s.gate_hist is None for s in self._queue):
            return self._queue.pop(0)
        hists = np.stack([s.gate_hist for s in self._queue])
        scores = np.asarray(rank_by_affinity(
            jnp.asarray(hists), jnp.asarray(self._batch_mass)))
        return self._queue.pop(int(scores.argmax()))

    def _first_token(self, logits: jnp.ndarray) -> jnp.ndarray:
        self._key, k = jax.random.split(self._key)
        return sample_step(logits, k, temperature=self.temperature)

    def _admit_group(self, group, now: float) -> None:
        """Prefill a group of same-shape admissions as ONE batched
        prefill and splice each row into its slot. Simultaneous arrivals
        (the all-at-t=0 case) therefore pay a single prefill dispatch —
        and run through the numerically identical computation the
        lockstep engine's batched prefill performs."""
        prompts = np.stack([st.req.prompt for st, _ in group])
        lg, req_cache, _ = self.fns.prefill(self.params, prompts)
        toks0 = self._first_token(lg)              # (G,) or (G, K)
        toks0_np = np.asarray(toks0)   # blocks: TTFT must include device time
        t_first = time.perf_counter() - self._t0
        if (len(group) == self.num_slots
                and [slot for _, slot in group] == list(range(len(group)))
                and not self._active.any()
                and all(st.req.max_new_tokens > 1 for st, _ in group)):
            # whole-batch admission into an empty machine (the all-at-t=0
            # case): the group prefill cache IS the running cache — skip
            # the per-slot insert dispatches entirely
            self._cache = req_cache
            self._tok = toks0
            for i, (st, slot) in enumerate(group):
                st.status = DECODE
                st.t_admitted = now
                st.tokens.append(toks0_np[i])
                st.t_first_token = t_first
                st.slot = slot
                self._slots[slot] = st
                self._active[slot] = True
            return
        for i, (st, slot) in enumerate(group):
            st.status = PREFILL
            st.t_admitted = now
            st.tokens.append(toks0_np[i])
            st.t_first_token = t_first
            if len(st.tokens) >= st.req.max_new_tokens:
                self._finish(st, slot=None)
                continue
            self._cache = self.fns.insert(
                self._cache, req_cache, jnp.asarray(slot, jnp.int32),
                jnp.asarray(i, jnp.int32))
            self._tok = self._tok.at[slot].set(toks0[i])
            self._slots[slot] = st
            self._active[slot] = True
            st.slot = slot
            st.status = DECODE

    def _finish(self, st: RequestState, slot: Optional[int]) -> None:
        st.status = DONE
        st.t_done = time.perf_counter() - self._t0
        if st.gate_hist is not None:       # admitted => counted in mass
            self._batch_mass -= st.gate_hist
        if slot is not None:
            self._cache = self.fns.evict(self._cache,
                                         jnp.asarray(slot, jnp.int32))
            self._slots[slot] = None
            self._active[slot] = False
            st.slot = -1

    def _fill_slots(self, now: float) -> None:
        free = [s for s in range(self.num_slots) if self._slots[s] is None]
        picks = []
        while free and self._queue:
            st = self._pick_next()         # greedy: sees mass so far
            if st.gate_hist is not None:
                self._batch_mass += st.gate_hist
            picks.append((st, free.pop(0)))
        # batch same-shape prompts into one prefill dispatch
        by_shape: Dict = {}
        for st, slot in picks:
            by_shape.setdefault(st.req.prompt.shape, []).append((st, slot))
        for group in by_shape.values():
            self._admit_group(group, now)

    # ------------------------------------------------------------ decode --

    def _decode_round(self) -> None:
        """One fused N-token scan + harvest. Slots carry their remaining
        token budget on device, so a request that finishes mid-chunk
        stops computing (and influencing XShare selection) on the next
        step, not at the chunk boundary."""
        remaining = np.asarray(
            [st.req.max_new_tokens - len(st.tokens) if st else 0
             for st in self._slots], np.int32)
        self._key, k = jax.random.split(self._key)
        self._tok, self._cache, toks, aux = self.fns.fused(
            self.params, self._tok, self._cache,
            jnp.asarray(remaining), k)
        toks = np.asarray(toks)                    # sync point: (N, B[,K])
        now = time.perf_counter() - self._t0
        N = toks.shape[0]
        self.total_steps += N
        aux_np = {kk: np.asarray(v) for kk, v in aux.items()}
        step_auxs = [{kk: v[i] for kk, v in aux_np.items()}
                     for i in range(N)]
        self.step_aux.extend(step_auxs)
        for slot, st in enumerate(self._slots):
            if st is None:
                continue
            take = min(N, st.req.max_new_tokens - len(st.tokens))
            st.tokens.extend(toks[i, slot] for i in range(take))
            st.layer_aux.extend(step_auxs[:take])
            if len(st.tokens) >= st.req.max_new_tokens:
                self._finish(st, slot=slot)

    # --------------------------------------------------------------- run --

    def run(self) -> List[RequestState]:
        """Serve every submitted request to completion. Arrival times are
        honored against the wall clock (arrival_s is relative to this
        call). Returns RequestStates in submission order."""
        self._t0 = time.perf_counter()
        self._incoming.sort(key=lambda s: s.req.arrival_s)
        while self._incoming or self._queue or self._active.any():
            now = time.perf_counter() - self._t0
            while self._incoming and \
                    self._incoming[0].req.arrival_s <= now:
                self._queue.append(self._incoming.pop(0))
            self._fill_slots(now)
            if self._active.any():
                self._decode_round()
            elif self._incoming:
                time.sleep(min(
                    0.01, max(0.0, self._incoming[0].req.arrival_s - now)))
        self.wall_s = time.perf_counter() - self._t0
        return self._states

    @property
    def elapsed_s(self) -> float:
        """Serve wall clock: live while run() is in flight, frozen at its
        end, 0.0 before the first run()."""
        if self._t0 is None:
            return 0.0
        return self.wall_s or (time.perf_counter() - self._t0)
