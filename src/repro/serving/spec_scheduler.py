"""Speculative decoding as a first-class scheduler subsystem.

SpecScheduler extends the continuous-batching Scheduler with a draft
model whose cache rows live and die with the target's slots, so
speculative and plain requests share ONE running batch under the
existing lifecycle (waiting -> prefill -> decode -> done | shed):

  * admission  — a speculative request pays one extra batched draft
                 prefill; its draft cache row is spliced next to the
                 target row and both advance in lockstep thereafter
                 (draft cur_len == target cur_len is an invariant).
  * decode     — rounds dispatch through serving/step.py
                 spec_steps_fused: an inner draft lax.scan plus a single
                 verify pass over (B, 1+L_s) tokens, ragged acceptance
                 via greedy_accept / per-slot rollback. Plain requests
                 ride the same dispatch with a zero draft limit (their
                 round is exactly plain greedy decode), so a
                 heterogeneous batch needs no second compiled path.
  * finish     — eviction (completion, cancel, deadline, numerics
                 quarantine) evicts BOTH cache rows; poisoned slots
                 scrub both.

Per-slot speculative state (host side, adjusted between dispatches):

  * adaptive draft length — an acceptance-rate EMA per slot grows the
    draft window toward spec_len while drafts keep landing and shrinks
    it toward 1 when the target keeps rejecting, so a slot whose draft
    model has gone off-distribution stops wasting verify width.
  * spec budget — each request may spend at most `budget` draft tokens;
    an exhausted slot keeps its draft cache in lockstep (the fused step
    still drafts) but accepts nothing, degrading to plain decode
    mid-request instead of failing.
  * correlation priors — per-request gate histograms, seeded from the
    admission router probe and EMA-updated from every verify pass's
    per-request histogram (route() aux "req_gate_hist"). Fed back into
    Algorithm-4 spec selection as `spec_priors`, they make the
    hierarchical selection correlation-aware ACROSS rounds: experts a
    request has favored before win ties over one-off spikes in the
    current draft window, shrinking the activated set at equal
    acceptance rate.

Greedy-only: speculative acceptance is exact under argmax (the
scheduler-integrated path is token-identical to the lockstep
Engine._generate_spec reference and to plain greedy decode);
temperature > 0 would need stochastic speculative sampling, which this
subsystem does not implement.
"""
from __future__ import annotations

import time
from dataclasses import dataclass
from typing import Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.models import init_cache
from repro.serving.errors import (REASON_COMPLETED, REASON_DEADLINE_E2E,
                                  REASON_NUMERICS, InvariantViolation)
from repro.serving.scheduler import (DECODE, RequestState, Scheduler,
                                     tighten_policy)
from repro.serving.step import (NO_FAULT, SpecStepFns, build_spec_fns,
                                make_spec_fused)


@dataclass(frozen=True)
class SpecConfig:
    """Speculative-decoding knobs for the SpecScheduler."""
    spec_len: int                 # max draft tokens per round (static)
    num_rounds: int = 4           # draft-verify rounds per fused dispatch
    budget: Optional[int] = None  # draft tokens a request may spend
    #                               (None = unlimited)
    adapt: bool = True            # adaptive per-slot draft length
    min_draft: int = 1            # floor (0 could never recover: the
    #                               acceptance EMA stops updating)
    ema_beta: float = 0.5         # acceptance-EMA smoothing
    grow_above: float = 0.8       # EMA >= this -> draft_len += 1
    shrink_below: float = 0.4     # EMA <  this -> draft_len -= 1
    prior_beta: float = 0.3       # correlation-prior EMA step size

    def __post_init__(self):
        if self.spec_len < 1:
            raise ValueError(f"spec_len must be >= 1, got {self.spec_len}")
        if not 1 <= self.min_draft <= self.spec_len:
            raise ValueError(
                f"min_draft must be in [1, spec_len], got {self.min_draft}")


@dataclass
class _SlotSpec:
    """Host-side speculative state of one occupied slot."""
    draft_len: int
    acc_ema: float = 1.0          # optimistic start: first round drafts
    budget_left: int = 2 ** 30    # effectively unlimited unless set
    prior: Optional[np.ndarray] = None   # (E,) float64 gate histogram


# budget sentinel handed to the fused step for slots without one: large
# enough to never clamp, small enough that int32 arithmetic cannot wrap
# (num_rounds * spec_len per dispatch is subtracted at most)
_NO_BUDGET = 2 ** 30


class SpecScheduler(Scheduler):
    """Continuous-batching scheduler with a resident draft model.

    Accepts every Scheduler knob; adds the (draft config, draft params)
    pair, a SpecConfig, and a SpecStepFns bundle. Requests opt in per
    submit() (spec=None defaults to speculative — the scheduler exists
    because the engine has a draft model); spec=False rides along as a
    plain request in the same batch.
    """

    def __init__(self, cfg: ArchConfig, params, *,
                 draft: Tuple[ArchConfig, dict],
                 spec_cfg: SpecConfig,
                 spec_fns: Optional[SpecStepFns] = None,
                 spec_fused_cache: Optional[Dict[int, Callable]] = None,
                 **sched_kw):
        super().__init__(cfg, params, **sched_kw)
        if self.temperature != 0.0:
            raise ValueError(
                "SpecScheduler is greedy-only (temperature == 0): "
                "speculative acceptance is exact under argmax")
        if cfg.family == "audio":
            raise NotImplementedError("spec decode for codebook streams")
        self.dcfg, self.dparams = draft
        self.spec_cfg = spec_cfg
        if self.policy.mode not in ("off", "spec"):
            raise ValueError(
                f"SpecScheduler verify policy must be mode 'off' or "
                f"'spec', got {self.policy.mode!r} (the Engine maps "
                f"other modes to OFF before building the bundle)")
        self.spec_fns = spec_fns or build_spec_fns(
            cfg, self.dcfg, policy=self.policy,
            spec_len=spec_cfg.spec_len, num_rounds=spec_cfg.num_rounds,
            cache_len=self.cache_len, force_window=self._force_window,
            capacity_factor=self._capacity_factor, dispatch=self._dispatch)
        ddtype = jax.tree_util.tree_leaves(self.dparams)[0].dtype
        self._dcache = init_cache(self.dcfg, self.num_slots, self.cache_len,
                                  ddtype)
        self._slot_spec: List[Optional[_SlotSpec]] = [None] * self.num_slots
        self._spec_fused_levels: Dict[int, Callable] = \
            spec_fused_cache if spec_fused_cache is not None else {}
        self._spec_fused_levels.setdefault(0, self.spec_fns.fused)
        # aggregate counters (mirrored per request on RequestState)
        self.total_drafted = 0
        self.total_accepted = 0
        self.budget_exhausted_events = 0
        # per-round mean accepted drafts over slots that drafted — the
        # continuous-path analogue of GenStats.accepted_hist
        self.round_accept_hist: List[float] = []

    # ------------------------------------------------------- submission --

    def _resolve_spec(self, spec: Optional[bool]) -> bool:
        return True if spec is None else bool(spec)

    # -------------------------------------------------------- admission --

    def _admit_group(self, group, now: float) -> None:
        """Target-side admission first (batched prefill + splice / the
        whole-batch fast path), then one batched DRAFT prefill for the
        group's speculative members and a per-slot splice into the draft
        cache. The draft cache row starts at cur_len == prompt_len ==
        the target row's cur_len, which the fused step then maintains."""
        super()._admit_group(group, now)
        spec_members = [(st, st.slot) for st, _ in group
                        if st.req.spec and st.slot >= 0
                        and st.status == DECODE]
        for st, slot in group:
            if st.slot >= 0 and st.status == DECODE:
                self._slot_spec[st.slot] = None   # plain default
        if not spec_members:
            return
        prompts = np.stack([st.req.prompt for st, _ in spec_members])
        _, dreq_cache, _ = self.spec_fns.dprefill(self.dparams, prompts)
        for i, (st, slot) in enumerate(spec_members):
            self._dcache = self.fns.insert(
                self._dcache, dreq_cache, jnp.asarray(slot, jnp.int32),
                jnp.asarray(i, jnp.int32))
            prior = None
            if st.gate_hist is not None:
                prior = np.asarray(st.gate_hist, np.float64).copy()
            elif self.fns.probe is not None:
                prior = np.asarray(
                    self.fns.probe(self.params, st.req.prompt[None]),
                    np.float64)
            self._slot_spec[slot] = _SlotSpec(
                draft_len=self.spec_cfg.spec_len,
                budget_left=(self.spec_cfg.budget
                             if self.spec_cfg.budget is not None
                             else _NO_BUDGET),
                prior=prior)

    # --------------------------------------------------------- lifecycle --

    def _finish(self, st: RequestState, slot: Optional[int],
                reason: str = REASON_COMPLETED, scrub: bool = False) -> None:
        if slot is not None and self._slot_spec[slot] is not None:
            evict = self.fns.evict_scrub if scrub else self.fns.evict
            self._dcache = evict(self._dcache, jnp.asarray(slot, jnp.int32))
            self._slot_spec[slot] = None
        super()._finish(st, slot, reason=reason, scrub=scrub)

    # ----------------------------------------------------- expert priors --

    def gate_priors(self) -> np.ndarray:
        """Spec override: the EMA-maintained verify-pass priors
        (``_SlotSpec.prior``, updated each round from the route() aux
        ``req_gate_hist``) — fresher than the base class's static
        admission-time histograms. Plain-decode members of the batch
        stay zero, exactly as Algorithm-4 selection expects (they are
        outside the per-request budget problem)."""
        E = self.cfg.moe.num_experts if self.cfg.moe else 0
        out = np.zeros((self.num_slots, E), np.float64)
        if E:
            for s, sp in enumerate(self._slot_spec):
                if sp is not None and sp.prior is not None:
                    out[s] = sp.prior
        return out

    # ------------------------------------------------------------ decode --

    def _spec_fused_at(self, level: int) -> Callable:
        if level == 0 or self.cfg.moe is None:
            return self.spec_fns.fused
        if level not in self._spec_fused_levels:
            pol = tighten_policy(self.policy, level, self.cfg.moe)
            self._spec_fused_levels[level] = make_spec_fused(
                self.cfg, self.dcfg, policy=pol,
                spec_len=self.spec_fns.spec_len,
                num_rounds=self.spec_fns.num_rounds,
                force_window=self._force_window,
                capacity_factor=self._capacity_factor,
                dispatch=self._dispatch)
        return self._spec_fused_levels[level]

    def _decode_round(self) -> None:
        """One fused dispatch of `num_rounds` draft-verify rounds +
        harvest. total_steps counts ROUNDS (each emits 1..spec_len+1
        tokens per live slot), so fault campaigns address rounds the way
        they address steps on the plain path. Between dispatches the
        host adapts per-slot draft lengths from the acceptance EMA,
        charges spec budgets, and folds the verify pass's per-request
        gate histograms into the correlation priors."""
        t_round = time.perf_counter()
        sc = self.spec_cfg
        R = self.spec_fns.num_rounds
        if self.faults is not None:
            self.faults.before_round(self._round_idx)
            fault = self.faults.nan_fault(self.total_steps,
                                          self.total_steps + R)
        else:
            fault = NO_FAULT
        remaining = np.asarray(
            [st.req.max_new_tokens - len(st.tokens) if st else 0
             for st in self._slots], np.int32)
        spec_on = np.asarray([sp is not None for sp in self._slot_spec],
                             bool)
        draft_len = np.asarray(
            [sp.draft_len if sp else 0 for sp in self._slot_spec], np.int32)
        budget = np.asarray(
            [min(sp.budget_left, _NO_BUDGET) if sp else 0
             for sp in self._slot_spec], np.int32)
        priors = self.gate_priors().astype(np.float32)
        (self._tok, self._cache, self._dcache, _, _,
         new_tokens, num_new, accepted, drafted, aux, poisoned) = \
            self._spec_fused_at(self.level)(
                self.params, self.dparams, self._tok, self._cache,
                self._dcache, jnp.asarray(remaining), jnp.asarray(budget),
                jnp.asarray(draft_len), jnp.asarray(spec_on),
                jnp.asarray(priors), jnp.asarray(fault, jnp.int32))
        new_tokens = np.asarray(new_tokens)        # sync: (R, B, Ls+1)
        num_new = np.asarray(num_new)              # (R, B)
        accepted = np.asarray(accepted)            # (R, B)
        drafted = np.asarray(drafted)              # (R, B) = lim
        poisoned = np.asarray(poisoned)            # (B,)
        dt = time.perf_counter() - t_round
        if self.watchdog_s is not None and dt > self.watchdog_s:
            self.stall_events += 1
        now = self._now()
        self.total_steps += R
        self._round_idx += 1
        aux_np = {k: np.asarray(v) for k, v in aux.items()}
        hist = aux_np.pop("req_gate_hist", None)   # (R, L, B, E) | None
        step_auxs = [{k: v[r] for k, v in aux_np.items()}
                     for r in range(R)]
        self.step_aux.extend(step_auxs)
        for r in range(R):
            dmask = drafted[r] > 0
            if dmask.any():
                self.round_accept_hist.append(
                    float(accepted[r][dmask].mean()))
        for slot, st in enumerate(self._slots):
            if st is None:
                continue
            sp = self._slot_spec[slot]
            for r in range(R):
                n = min(int(num_new[r, slot]),
                        st.req.max_new_tokens - len(st.tokens))
                if n > 0:
                    st.tokens.extend(new_tokens[r, slot, :n])
                    st.layer_aux.append(step_auxs[r])
                d = int(drafted[r, slot])
                if d > 0:
                    a = int(accepted[r, slot])
                    st.drafted += d
                    st.accepted_drafts += a
                    self.total_drafted += d
                    self.total_accepted += a
                    if sp is not None:
                        sp.acc_ema = (sc.ema_beta * sp.acc_ema
                                      + (1.0 - sc.ema_beta) * (a / d))
                        sp.budget_left -= d
                if (sp is not None and hist is not None and n > 0
                        and hist.shape[-1]):
                    h = hist[r, :, slot].mean(axis=0)      # (E,) over layers
                    sp.prior = h if sp.prior is None else \
                        (1.0 - sc.prior_beta) * sp.prior + sc.prior_beta * h
            if sp is not None:
                if sp.budget_left <= 0 and not st.spec_budget_exhausted:
                    st.spec_budget_exhausted = True
                    self.budget_exhausted_events += 1
                    sp.budget_left = 0
                if sc.adapt:
                    if sp.acc_ema >= sc.grow_above:
                        sp.draft_len = min(sp.draft_len + 1,
                                           self.spec_fns.spec_len)
                    elif sp.acc_ema < sc.shrink_below:
                        sp.draft_len = max(sp.draft_len - 1, sc.min_draft)
            if poisoned[slot]:
                self._finish(st, slot=slot, reason=REASON_NUMERICS,
                             scrub=True)
            elif len(st.tokens) >= st.req.max_new_tokens:
                self._finish(st, slot=slot)
        harvested = int(num_new.sum())
        if harvested and dt > 0:
            rate = harvested / dt
            self._otps_ema = rate if self._otps_ema is None \
                else 0.5 * self._otps_ema + 0.5 * rate
        for slot, st in enumerate(self._slots):
            if st is not None and st.req.deadline_s is not None and \
                    now > st.req.arrival_s + st.req.deadline_s:
                self._finish(st, slot=slot, reason=REASON_DEADLINE_E2E)
        if self.on_round is not None:
            self.on_round(self, self._round_idx)

    # -------------------------------------------------------- reporting --

    @property
    def acceptance_rate(self) -> float:
        """Accepted / drafted over the whole serve (0.0 before any
        draft was proposed)."""
        return self.total_accepted / self.total_drafted \
            if self.total_drafted else 0.0

    # -------------------------------------------------------- invariants --

    def check_invariants(self) -> None:
        super().check_invariants()
        dcur = np.asarray(self._dcache["cur_len"])
        cur = np.asarray(self._cache["cur_len"])
        for s in range(self.num_slots):
            sp = self._slot_spec[s]
            st = self._slots[s]
            if sp is not None and (st is None or not st.req.spec):
                raise InvariantViolation(
                    f"slot {s}: speculative state without a speculative "
                    f"occupant")
            if sp is not None:
                if dcur[s] != cur[s]:
                    raise InvariantViolation(
                        f"slot {s}: draft cur_len {dcur[s]} != target "
                        f"cur_len {cur[s]}")
                if not (self.spec_cfg.min_draft <= sp.draft_len
                        <= self.spec_fns.spec_len):
                    raise InvariantViolation(
                        f"slot {s}: draft_len {sp.draft_len} outside "
                        f"[{self.spec_cfg.min_draft}, "
                        f"{self.spec_fns.spec_len}]")
                if sp.budget_left < 0:
                    raise InvariantViolation(
                        f"slot {s}: negative spec budget {sp.budget_left}")
            elif st is None and dcur[s] != 0:
                raise InvariantViolation(
                    f"empty slot {s} has draft cur_len {dcur[s]} != 0")
