"""Serving example: batched requests + EAGLE-style speculative decoding
with the paper's Algorithm 4 (hierarchical per-request expert selection)
on the verify batches.

    PYTHONPATH=src python examples/serve_spec_decode.py
"""
import dataclasses

import jax
import numpy as np

from repro.configs.base import XSharePolicy
from repro.configs.registry import get_config
from repro.data import make_dataset_family, mixed_request_batch
from repro.models import init_params, param_count
from repro.serving import Engine


def main() -> None:
    # target: reduced granite-MoE; draft: 2-layer dense with same vocab
    cfg = get_config("granite-moe-1b-a400m").reduced(
        num_layers=4, max_d_model=256, max_experts=4, max_vocab=512)
    params = init_params(cfg, jax.random.PRNGKey(0))
    # draft: lightly perturbed copy of the target (untrained weights make
    # an independent draft accept ~nothing; a perturbed twin shows the
    # ragged-acceptance machinery the way a distilled EAGLE head would)
    dcfg = cfg
    dparams = jax.tree_util.tree_map(
        lambda a: a + 0.01 * jax.random.normal(jax.random.PRNGKey(7),
                                               a.shape, a.dtype),
        params)
    print(f"target {param_count(params)/1e6:.1f}M / "
          f"draft {param_count(dparams)/1e6:.1f}M, spec len 3")

    # heterogeneous batch: one request per synthetic dataset (Sec 6.3)
    fam = make_dataset_family(cfg.vocab_size,
                              ["gpqa", "aime", "mmlu", "lcr"])
    prompts = mixed_request_batch(fam, seq_len=16, seed=0)

    runs = [
        ("plain decode", None, 0, XSharePolicy(mode="off")),
        ("spec decode", (dcfg, dparams), 3, XSharePolicy(mode="off")),
        ("spec + Alg4 (k0=1, m_r=2)", (dcfg, dparams), 3,
         XSharePolicy(mode="spec", k0=1, m_l=0, m_r=2)),
    ]
    ref = None
    for name, draft, spec_len, pol in runs:
        eng = Engine(cfg, params, policy=pol, cache_len=128, draft=draft,
                     spec_len=spec_len)
        toks, st = eng.generate(prompts, 32)
        line = (f"{name:28s} OTPS {st.otps:7.1f}  steps {st.steps:3d}")
        if st.accepted_hist:
            line += f"  acc/step {st.mean_accepted:.2f}"
        if st.layer_aux:
            line += (f"  experts/layer {st.mean_aux('activated_experts'):.1f}"
                     f" (set {st.mean_aux('selected_set'):.1f})")
        print(line)
        if ref is None:
            ref = toks
        elif pol.mode == "off":
            print(f"{'':28s} lossless vs plain: "
                  f"{np.array_equal(ref, toks)}")


if __name__ == "__main__":
    main()
