"""Serving example: speculative decoding as a scheduler subsystem.

Mixed speculative + plain requests stream through the crash-tolerant
FrontDoor over one SpecScheduler batch: per-slot adaptive draft lengths,
spec budgets, and the paper's Algorithm 4 (hierarchical, correlation-
aware per-request expert selection) on the verify batches.

    PYTHONPATH=src python examples/serve_spec_decode.py
"""
import jax
import numpy as np

from repro.configs.base import XSharePolicy
from repro.configs.registry import get_config
from repro.data import make_dataset_family, mixed_request_batch
from repro.models import init_params, param_count
from repro.serving import Engine


def main() -> None:
    # target: reduced granite-MoE; draft: lightly perturbed copy of the
    # target (untrained weights make an independent draft accept
    # ~nothing; a perturbed twin shows the ragged-acceptance machinery
    # the way a distilled EAGLE head would)
    cfg = get_config("granite-moe-1b-a400m").reduced(
        num_layers=4, max_d_model=256, max_experts=4, max_vocab=512)
    params = init_params(cfg, jax.random.PRNGKey(0))
    dparams = jax.tree_util.tree_map(
        lambda a: a + 0.01 * jax.random.normal(jax.random.PRNGKey(7),
                                               a.shape, a.dtype),
        params)
    print(f"target {param_count(params)/1e6:.1f}M / "
          f"draft {param_count(dparams)/1e6:.1f}M, spec len 3")

    # heterogeneous traffic: one request per synthetic dataset (Sec 6.3)
    fam = make_dataset_family(cfg.vocab_size,
                              ["gpqa", "aime", "mmlu", "lcr"])
    prompts = mixed_request_batch(fam, seq_len=16, seed=0)
    B, max_new = prompts.shape[0], 32

    # plain greedy reference — the losslessness yardstick for everything
    plain_eng = Engine(cfg, params, cache_len=128)
    ref, ref_st = plain_eng.generate(prompts, max_new)
    print(f"{'plain decode':34s} OTPS {ref_st.otps:7.1f}  "
          f"steps {ref_st.steps:3d}")

    for name, pol in [
        ("sched-spec", XSharePolicy(mode="off")),
        ("sched-spec + Alg4 (k0=1, m_r=2)",
         XSharePolicy(mode="spec", k0=1, m_l=0, m_r=2)),
    ]:
        eng = Engine(cfg, params, policy=pol, cache_len=128,
                     draft=(cfg, dparams), spec_len=3)
        toks, st = eng.generate(prompts, max_new)
        line = (f"{name:34s} OTPS {st.otps:7.1f}  rounds {st.steps:3d}"
                f"  acc rate {st.acceptance_rate:.2f}")
        if st.layer_aux:
            line += (f"  experts/layer "
                     f"{st.mean_aux('activated_experts'):.1f}")
        if pol.mode == "off":
            line += f"  lossless: {np.array_equal(ref, toks)}"
        print(line)

    # ---- mixed spec+plain traffic through the streaming front door ----
    eng = Engine(cfg, params, cache_len=128, draft=(cfg, dparams),
                 spec_len=3)
    door = eng.make_frontdoor(num_slots=2)   # fewer slots than requests
    streams = [door.submit(prompts[b], max_new, spec=(b % 2 == 0))
               for b in range(B)]
    live = [t for t in streams[0]]           # consume one stream live
    door.drain(timeout=300.0)
    print(f"\nfront door: {B} requests ({B - B // 2} spec, {B // 2} "
          f"plain) on 2 slots; first stream delivered "
          f"{len(live)} tokens live")
    for b, s in enumerate(streams):
        kind = "spec " if s.spec else "plain"
        exact = np.array_equal(np.asarray(s.tokens), ref[b])
        print(f"  req {b} [{kind}] {s.finish_reason:10s} "
              f"{len(s.tokens):2d} tokens  lossless: {exact}")


if __name__ == "__main__":
    main()
