"""Continuous-batching serving demo: Poisson request traffic over
heterogeneous synthetic datasets, served from a fixed-slot running batch
with fused multi-token decode, under FIFO vs. XShare-affinity admission
(batch composition by expert-gate-histogram overlap) — then the same
traffic under a fault-injection campaign with the robustness layer
armed (deadlines, cancellation, bounded queue, watchdog, graceful
XShare degradation, numerics quarantine).

    PYTHONPATH=src python examples/serve_continuous.py
"""
import jax
import numpy as np

from repro.configs.registry import get_config
from repro.data import make_dataset_family
from repro.models import init_params, param_count
from repro.serving import Engine, Fault, FaultInjector


def main() -> None:
    cfg = get_config("granite-moe-1b-a400m").reduced(
        num_layers=4, max_d_model=256, max_experts=8, max_vocab=512)
    params = init_params(cfg, jax.random.PRNGKey(0))
    print(f"model {param_count(params)/1e6:.1f}M params, "
          f"{cfg.moe.num_experts} experts (top-{cfg.moe.top_k})")

    fam = make_dataset_family(cfg.vocab_size,
                              ["gpqa", "aime", "mmlu", "lcr"])
    names = list(fam)
    rng = np.random.default_rng(0)
    n_req, slots, max_new = 12, 3, 24
    prompts = [fam[names[i % len(names)]].sample(rng, 1, 16)[0]
               for i in range(n_req)]
    arrivals = np.cumsum(rng.exponential(1 / 20.0, n_req))

    eng = Engine(cfg, params, cache_len=64, decode_chunk=8)
    # compile before timing: staggered arrivals into fewer slots also
    # hit the partial-group prefill and insert paths
    warm = eng.make_scheduler(num_slots=slots)
    for i, p in enumerate(prompts[:slots + 2]):
        warm.submit(p, 9, arrival_s=0.05 * i)
    warm.run()
    for admission in ("fcfs", "affinity"):
        sched = eng.make_scheduler(num_slots=slots, admission=admission)
        for i, (p, t) in enumerate(zip(prompts, arrivals)):
            sched.submit(p, max_new, arrival_s=float(t))
        states = sched.run()
        toks = sum(len(s.tokens) for s in states)
        lat = np.array([s.latency_s for s in states])
        acts = [float(np.mean(a["activated_experts"]))
                for a in sched.step_aux]
        print(f"\n--- admission={admission} "
              f"({n_req} requests -> {slots} slots) ---")
        print(f"OTPS {toks / sched.elapsed_s:7.1f}   "
              f"p50 latency {np.percentile(lat, 50)*1e3:6.0f} ms   "
              f"p99 {np.percentile(lat, 99)*1e3:6.0f} ms   "
              f"experts/layer-step {np.mean(acts):.2f}")
        for st in states:
            dom = names[st.req.rid % len(names)]
            print(f"  req {st.req.rid:2d} [{dom:4s}] "
                  f"arrive {st.req.arrival_s*1e3:5.0f} ms  "
                  f"ttft {st.ttft_s*1e3:6.0f} ms  "
                  f"done {st.t_done*1e3:6.0f} ms  "
                  f"tokens {len(st.tokens)}")

    # --- robustness: same traffic, hostile conditions ---------------------
    inj = FaultInjector([
        Fault("nan_logits", slot=1, step=12),      # device numerics
        Fault("insert_fail", rid=5, times=1),      # transient cache splice
        Fault("stall_decode", step=3, delay_s=0.05),
    ])
    sched = eng.make_scheduler(
        num_slots=slots, admission="affinity", faults=inj,
        invariants=True, watchdog_s=0.25, max_retries=2,
        retry_backoff_s=0.01, max_queue=n_req, overload="shed",
        degrade=True)
    for i, (p, t) in enumerate(zip(prompts, arrivals)):
        kw = dict(ttft_deadline_s=20.0, deadline_s=40.0) if i % 4 == 3 \
            else {}
        sched.submit(p, max_new, arrival_s=float(t), **kw)
    sched.cancel(2)                                # caller walked away
    states = sched.run(max_wall_s=120.0)
    print(f"\n--- fault campaign ({len(inj.log)} faults delivered, "
          f"{sched.retries} retries, {sched.stall_events} stalls, "
          f"peak degrade level "
          f"{max((l for _, l in sched.degrade_events), default=0)}) ---")
    print("  terminal reasons:", sched.reason_counts())
    sched.check_invariants()
    assert all(s is None for s in sched._slots)
    print("  invariants clean, zero slot leaks after drain")


if __name__ == "__main__":
    main()
