"""Expert-parallel deployment demo, in two acts:

1. a replicated-token shard_map (the dispatch/combine all-to-all
   collapses to a psum — the paper's Sec 5 load accounting), comparing
   plain greedy selection vs Algorithm 6's GPU-aware selection on
   per-device load;
2. the REAL EP executor (`repro.ep.EPExecutor`): per-shard sorted
   dispatch, counts-first ragged all-to-all row exchange, grouped GEMM
   per shard — with measured per-shard computed rows and wire bytes,
   on a contiguous layout vs load-aware LPT placement vs hot-expert
   replication.

Runs on 8 forced host devices (set before jax import):

    PYTHONPATH=src python examples/ep_balance.py
"""
import os

os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=8 "
                           + os.environ.get("XLA_FLAGS", ""))

import functools                               # noqa: E402

import jax                                     # noqa: E402
import jax.numpy as jnp                        # noqa: E402
import numpy as np                             # noqa: E402
from jax.sharding import PartitionSpec as P    # noqa: E402

from repro.configs.base import MoEConfig, XSharePolicy  # noqa: E402
from repro.core.metrics import per_group_load  # noqa: E402
from repro.kernels.ref import moe_ffn_ref      # noqa: E402
from repro.models.moe import OFF, init_moe, route  # noqa: E402

G = 8                       # device groups == mesh "model" extent
E, K, D, F, T = 64, 8, 64, 128, 32

from repro.launch.mesh import make_mesh_compat  # noqa: E402

mesh = make_mesh_compat((G,), ("model",))

# jax.shard_map only exists in newer releases; older ones expose the
# experimental module
shard_map = getattr(jax, "shard_map", None)
if shard_map is None:
    from jax.experimental.shard_map import shard_map  # noqa: E402


@functools.partial(
    shard_map, mesh=mesh,
    in_specs=(P(), P("model"), P("model"), P("model"), P(), P()),
    out_specs=P())
def ep_forward(x, w1, w3, w2, combine, active):
    """Explicit expert parallelism: every device holds E/G experts;
    tokens are replicated in, each shard computes ITS experts' masked
    FFN contribution, and a psum combines — the dispatch/combine
    all-to-all of GShard collapses to a psum here because the demo
    replicates tokens (decode batches are small)."""
    g = jax.lax.axis_index("model")
    e_lo = g * (E // G)
    local_combine = jax.lax.dynamic_slice(combine, (0, e_lo),
                                          (T, E // G))
    local_active = jax.lax.dynamic_slice(active, (e_lo,), (E // G,))
    y_local = moe_ffn_ref(x, w1, w3, w2, local_combine, local_active)
    return jax.lax.psum(y_local, "model")


def main() -> None:
    moe = MoEConfig(num_experts=E, top_k=K, d_ff_expert=F)
    params = init_moe(jax.random.PRNGKey(0), moe, D, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (T, D))

    print(f"{E} experts over {G} devices ({E//G}/device), batch {T}, "
          f"top-{K}\n")
    for name, pol in [
            ("vanilla top-k", OFF),
            ("Alg 1 greedy (m=24)", XSharePolicy(mode="batch", k0=0,
                                                 m_l=24)),
            ("Alg 6 EP-aware (k0=1, m_g=3)",
             XSharePolicy(mode="ep", k0=1, m_g=3, num_groups=G))]:
        idx, w, combine, aux = route(params, x, moe, pol)
        active = (combine > 0).any(0)
        loads = np.asarray(per_group_load(active, G))
        y = ep_forward(x, params["w1"], params["w3"], params["w2"],
                       combine, active)
        ref = moe_ffn_ref(x, params["w1"], params["w3"], params["w2"],
                          combine, active)
        ok = bool(jnp.allclose(y, ref, atol=1e-4))
        print(f"{name:30s} active {int(active.sum()):2d}  "
              f"per-device {loads}  MaxLoad {loads.max()}  "
              f"shard_map==ref {ok}")
    print("\nLayer latency tracks MaxLoad (all shards sync at the "
          "combine); Alg 6 trades gate mass for a flat profile.")

    # ---- act 2: the real ragged-exchange executor --------------------
    from repro.ep import (EPExecutor, contiguous_placement,  # noqa: E402
                          plan_placement)
    from repro.models.dispatch import sorted_expert_ffn     # noqa: E402

    print("\nReal EP execution (ragged all-to-all + per-shard grouped "
          "GEMM), Alg 6 routing:")
    idx, w, _, _ = route(params, x, moe,
                         XSharePolicy(mode="ep", k0=1, m_g=3,
                                      num_groups=G))
    load = np.zeros(E)
    np.add.at(load, np.asarray(idx).reshape(-1).clip(0),
              np.asarray(w).reshape(-1) != 0)
    ref = sorted_expert_ffn(x, params["w1"], params["w3"], params["w2"],
                            idx, w)
    for name, pl in [
            ("contiguous", contiguous_placement(E, G)),
            ("LPT placement", plan_placement(load, G)),
            ("LPT + replicate hot x2",
             plan_placement(load, G, replicate_hot=2, max_replicas=2))]:
        ex = EPExecutor(mesh, pl,
                        replicate_hot=2 if "replicate" in name else 0,
                        max_replicas=2)
        y, st = ex(x, params["w1"], params["w3"], params["w2"], idx, w)
        ok = bool(np.array_equal(np.asarray(y), np.asarray(ref)))
        print(f"{name:24s} rows/shard {st.computed_rows.tolist()}  "
              f"peak {st.peak_rows}  a2a {st.total_a2a_bytes}B  "
              f"exact-vs-single-device {ok}")


if __name__ == "__main__":
    main()
