"""Crash-tolerant front-door demo: async token streaming with
mid-stream cancellation, then a process kill mid-decode and a full
recovery from the durable journal + snapshot — the recovered greedy
streams are bit-identical to an uninterrupted run.

    PYTHONPATH=src python examples/serve_frontdoor.py
"""
import os
import tempfile

import jax
import numpy as np

from repro.configs.registry import get_config
from repro.data import make_dataset_family
from repro.models import init_params, param_count
from repro.serving import (Engine, Fault, FaultInjector, FrontDoor,
                           RequestCancelled, recover)


def main() -> None:
    cfg = get_config("granite-moe-1b-a400m").reduced(
        num_layers=4, max_d_model=256, max_experts=8, max_vocab=512)
    params = init_params(cfg, jax.random.PRNGKey(0))
    print(f"model {param_count(params)/1e6:.1f}M params, "
          f"{cfg.moe.num_experts} experts (top-{cfg.moe.top_k})")

    fam = make_dataset_family(cfg.vocab_size, ["gpqa", "aime", "mmlu"])
    names = list(fam)
    rng = np.random.default_rng(0)
    n_req, slots, max_new = 6, 2, 24
    prompts = [fam[names[i % len(names)]].sample(rng, 1, 16)[0]
               for i in range(n_req)]
    eng = Engine(cfg, params, cache_len=64, decode_chunk=8)
    free, _ = eng.generate(np.stack(prompts), max_new)  # reference run

    # --- 1. live streaming + mid-stream cancel ---------------------------
    door = eng.make_frontdoor(num_slots=slots)
    streams = [door.submit(p, max_new) for p in prompts]
    it = iter(streams[1])
    first = [int(next(it)), int(next(it))]
    door.cancel(1)                                 # caller walked away
    print(f"\nstream 1: consumed {first} live, then cancelled "
          f"({len(first) + len(list(it))} tokens total)")
    door.drain()
    try:
        streams[1].result(timeout=1.0)
    except RequestCancelled as e:
        print(f"  result() -> RequestCancelled: {e}")
    survivors = [s for s in streams if s.rid != 1]
    assert all(np.array_equal(np.asarray([int(t) for t in s.tokens]),
                              free[s.rid]) for s in survivors)
    print(f"  {len(survivors)} surviving streams token-exact "
          f"vs. batch generate()")

    # --- 2. kill mid-decode, recover from journal + snapshot --------------
    tmp = tempfile.mkdtemp(prefix="xshare-frontdoor-")
    jp, sp = os.path.join(tmp, "wal.journal"), os.path.join(tmp, "snap")
    inj = FaultInjector([Fault("crash_mid_round", step=2),
                         Fault("journal_torn_write", nbytes=7)])
    door = FrontDoor(eng, num_slots=slots, journal_path=jp,
                     snapshot_path=sp, snapshot_every_rounds=1,
                     fsync_every=1, faults=inj).start()
    for p in prompts:
        door.submit(p, max_new)
    door.drain()
    print(f"\nprocess killed mid-round: {type(door.crashed).__name__}, "
          f"{door.snapshots_written} snapshot(s) on disk, "
          f"journal {os.path.getsize(jp)} bytes")

    door2, report = recover(eng, journal_path=jp, snapshot_path=sp,
                            num_slots=slots)
    print(f"recovery: {report.requests} journaled requests -> "
          f"{report.terminal} already terminal, {report.resumed} resumed"
          f"{' (torn journal tail repaired)' if report.torn_tail else ''}")
    door2.drain()
    stats = door2.replay_stats()
    for rid in sorted(door2.streams):
        s = door2.streams[rid]
        assert np.array_equal(np.asarray([int(t) for t in s.tokens]),
                              free[rid])
    print(f"  replay fidelity {stats['fidelity']:.3f} over "
          f"{int(stats['replayed_tokens'])} journaled tokens, "
          f"0 mismatches" if not stats["mismatches"] else stats)
    print(f"  all {n_req} recovered streams bit-identical to the "
          f"uninterrupted run")


if __name__ == "__main__":
    main()
