"""Quickstart: XShare batch-aware expert selection in 60 lines.

    PYTHONPATH=src python examples/quickstart.py

Builds a small MoE layer, routes a decode batch with vanilla top-k vs
the paper's three algorithms, and prints the activation statistics each
one is designed to optimize.
"""
import jax
import jax.numpy as jnp

from repro.configs.base import MoEConfig, XSharePolicy
from repro.core.metrics import gate_mass_captured, max_group_load
from repro.models.moe import OFF, init_moe, route

E, K, D, BATCH = 64, 8, 128, 16

moe = MoEConfig(num_experts=E, top_k=K, d_ff_expert=256)
params = init_moe(jax.random.PRNGKey(0), moe, D, jnp.float32)
x = jax.random.normal(jax.random.PRNGKey(1), (BATCH, D))  # decode batch

policies = {
    "vanilla top-k":            OFF,
    "Alg 2  batch (k0=1,m=16)": XSharePolicy(mode="batch", k0=1, m_l=16),
    "Alg 2  warm-up only":      XSharePolicy(mode="batch", k0=1, m_l=0),
    "Alg 4  spec (m_r=6)":      XSharePolicy(mode="spec", k0=1, m_l=0,
                                             m_r=6),
    "Alg 6  EP (m_g=3, G=8)":   XSharePolicy(mode="ep", k0=1, m_g=3,
                                             num_groups=8),
}

print(f"MoE: {E} experts, top-{K}, decode batch {BATCH}")
print(f"{'policy':28s} {'activated':>9s} {'selected':>8s} "
      f"{'max/GPU':>7s} {'gate mass':>9s}")
for name, pol in policies.items():
    spec_shape = (4, 4) if pol.mode == "spec" else None
    idx, w, combine, aux = route(params, x, moe, pol, spec_shape=spec_shape)
    print(f"{name:28s} {int(aux['activated_experts']):9d} "
          f"{int(aux['selected_set']):8d} "
          f"{int(aux['max_group_load']):7d} "
          f"{float(aux['gate_mass']):9.3f}")

print("\nEvery token still gets top-k routing WITHIN the selected set —")
print("fewer expert weights stream from HBM per decode step, which is")
print("the whole game in the memory-bound decode regime (paper Sec 1).")
