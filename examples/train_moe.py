"""End-to-end training driver: train a ~100M-parameter MoE LM for a few
hundred steps on the synthetic data pipeline, checkpoint it, and show
that XShare-at-decode preserves its quality.

    PYTHONPATH=src python examples/train_moe.py [--steps 300] [--small]

(--small trains a ~8M model for a fast demo run.)
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import save_checkpoint
from repro.configs.base import (ArchConfig, AttnConfig, MoEConfig,
                                XSharePolicy)
from repro.data import SyntheticLM, batches
from repro.launch.train import make_train_step
from repro.models import init_params, loss_fn, param_count
from repro.optim import adamw_init, cosine_schedule


def model_100m() -> ArchConfig:
    # ~104M params: 8 layers, d=512, 16 experts x top-2 of d_ff 1024
    return ArchConfig(
        name="xshare-demo-100m", family="moe", num_layers=8, d_model=512,
        d_ff=0, vocab_size=8192,
        attn=AttnConfig(num_heads=8, num_kv_heads=4, head_dim=64),
        moe=MoEConfig(num_experts=16, top_k=2, d_ff_expert=1024),
    )


def model_small() -> ArchConfig:
    return ArchConfig(
        name="xshare-demo-8m", family="moe", num_layers=4, d_model=128,
        d_ff=0, vocab_size=2048,
        attn=AttnConfig(num_heads=4, num_kv_heads=2, head_dim=32),
        moe=MoEConfig(num_experts=8, top_k=2, d_ff_expert=256),
    )


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--small", action="store_true")
    ap.add_argument("--ckpt", default="/tmp/xshare_moe_demo")
    args = ap.parse_args()

    cfg = model_small() if args.small else model_100m()
    params = init_params(cfg, jax.random.PRNGKey(0))
    print(f"model: {cfg.name}  params={param_count(params)/1e6:.1f}M")
    opt = adamw_init(params)
    step = jax.jit(make_train_step(
        cfg, lr=cosine_schedule(3e-4, 20, args.steps), remat=True,
        capacity_factor=2.0))

    lm = SyntheticLM(cfg.vocab_size, name="demo", branch=8)
    stream = batches(lm, batch=args.batch, seq_len=args.seq, seed=0)
    t0 = time.perf_counter()
    for i in range(args.steps):
        toks = jnp.asarray(next(stream))
        params, opt, m = step(params, opt, toks)
        if i % max(1, args.steps // 15) == 0 or i == args.steps - 1:
            tput = (i + 1) * args.batch * args.seq / (
                time.perf_counter() - t0)
            print(f"step {i:4d}  loss {float(m['loss']):.4f}  "
                  f"gnorm {float(m['grad_norm']):.2f}  {tput:.0f} tok/s")

    save_checkpoint(args.ckpt, params, step=args.steps)
    print("checkpoint:", args.ckpt + ".npz")

    # quality under XShare decode policies (teacher-forced eval)
    ev = jnp.asarray(next(batches(lm, batch=8, seq_len=args.seq,
                                  seed=99)))
    for name, pol in [
            ("baseline top-k", XSharePolicy(mode="off")),
            ("XShare (k0=1, m=E/8)",
             XSharePolicy(mode="batch", k0=1,
                          m_l=cfg.moe.num_experts // 8))]:
        ce, _ = loss_fn(cfg, params, ev, policy=pol, remat=False,
                        capacity_factor=8.0)
        print(f"eval CE  {name:22s} {float(ce):.4f}")


if __name__ == "__main__":
    main()
